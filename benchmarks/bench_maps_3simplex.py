"""Paper Fig. 13 — 3-simplex tests: MAP3D / ACCUM3D / CA3D for
{table (exact), octant (closed-form exact, ours), BB}.

The paper's theoretical MAP3D speedup is ~6x (BB launches n^3 blocks vs
tet(n) useful); the table schedule achieves exactly 6x asymptotically,
the octant closed form ~5x (its ~20% self-similar overhead), both far
from BB's +500%.  DP (CUDA dynamic parallelism) has no TPU analogue —
DESIGN.md §2.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

from repro.core.schedule import SimplexSchedule
from repro.kernels import ref as R
from repro.kernels import engine as K


def _time(f, *args, reps=2):
    f(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(n: int = 32, rho: int = 4):
    nb = n // rho
    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (n, n, n), 0, 50).astype(jnp.int32)
    ca = (jax.random.uniform(key, (n, n, n)) < 0.35).astype(jnp.int32)
    ca = ca * R.tetra_mask(n, jnp.int32)
    rows = []
    tests = {
        "ACCUM3D": lambda kind: functools.partial(K.accum, x, rho=rho, kind=kind),
        "CA3D": lambda kind: functools.partial(K.ca, ca, rho=rho, kind=kind),
    }
    def sched(nb_, kind):
        return SimplexSchedule(3, nb_, kind)

    # MAP3D is the pure schedule-walk ratio (no payload):
    for kind in ["table", "octant", "bb"]:
        s = sched(nb, kind)
        rows.append({
            "test": "MAP3D", "map": kind, "m": 3, "n": n,
            "grid_steps": s.steps, "waste": s.waste(),
            "space_speedup_vs_bb": sched(nb, "bb").steps / s.steps,
            "us_per_call": float("nan"),
            "wall_speedup_vs_bb": float("nan"),
        })
    for tname, mk in tests.items():
        bb_us = _time(jax.jit(mk("bb")))
        for kind in ["table", "octant", "bb"]:
            s = sched(nb, kind)
            us = bb_us if kind == "bb" else _time(jax.jit(mk(kind)))
            rows.append({
                "test": tname, "map": kind, "m": 3, "n": n,
                "grid_steps": s.steps, "waste": s.waste(),
                "space_speedup_vs_bb": sched(nb, "bb").steps / s.steps,
                "us_per_call": us,
                "wall_speedup_vs_bb": bb_us / us,
            })
    # asymptotic block-space ratios at production scale (structural)
    for nb_big in [128, 512]:
        for kind in ["table", "octant"]:
            s = sched(nb_big, kind)
            rows.append({
                "test": f"MAP3D(nb={nb_big})", "map": kind, "m": 3,
                "n": nb_big * rho,
                "grid_steps": s.steps, "waste": s.waste(),
                "space_speedup_vs_bb": sched(nb_big, "bb").steps / s.steps,
                "us_per_call": float("nan"),
                "wall_speedup_vs_bb": float("nan"),
            })
    return rows


def main():
    rows = run()
    print("test,map,grid_steps,space_speedup_vs_bb,us_per_call,wall_speedup_vs_bb")
    for r in rows:
        print(f"{r['test']},{r['map']},{r['grid_steps']},"
              f"{r['space_speedup_vs_bb']:.3f},{r['us_per_call']:.0f},"
              f"{r['wall_speedup_vs_bb']:.2f}")
    return rows


if __name__ == "__main__":
    main()
