"""Beyond-paper table: the simplex schedule applied to causal attention.

Two sections:

* ``run()`` — the *compiled XLA* microbenchmark (repro.models.attention):
  real matmul work on this host, no interpreter overhead: the folded
  schedule runs ~tri(n)/n^2 of BB's block FLOPs, so wall-clock speedup
  should approach 2x as nq grows.  Also reports the Pallas kernel's
  grid-step counts (the TPU-structural quantity) per (seq, block) shape.
* ``serving_rows()`` — the serving metric (DESIGN.md §8): tokens/s for
  batched prefill + decode at ``examples/serve_lm.py``'s workload
  (reduced yi-6b, batch 4, prompt 64), with the attention executor
  pinned per row to kind in {bb, folded, chunked} via
  ``cfg.attention_impl``.  These are the bench-maps/v2 ATTN rows the
  ``choose_attn_impl`` autotuner consumes as measured evidence (only
  when ``compiled: true`` — flash rows on interpret hosts record the
  emulator and are marked accordingly).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_grid_steps
from repro.models.attention import chunked_causal_attention


def _time(f, *args, reps=3):
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    for (b, h, s, d, chunk) in [
        (1, 4, 1024, 64, 128),
        (1, 4, 2048, 64, 256),
        (1, 8, 4096, 64, 256),
    ]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, h, s, d), dtype=jnp.float32)
        k = jax.random.normal(ks[1], (b, h, s, d), dtype=jnp.float32)
        v = jax.random.normal(ks[2], (b, h, s, d), dtype=jnp.float32)
        nq = s // chunk
        us = {}
        for sched in ["bb", "folded"]:
            f = jax.jit(
                lambda q, k, v, sched=sched: chunked_causal_attention(
                    q, k, v, chunk=chunk, schedule=sched
                )
            )
            us[sched] = _time(f, q, k, v)
        rows.append({
            "shape": f"B{b}H{h}S{s}D{d}/c{chunk}",
            "bb_us": us["bb"],
            "folded_us": us["folded"],
            "wall_speedup": us["bb"] / us["folded"],
            "grid_steps_bb": flash_grid_steps(nq, "bb"),
            "grid_steps_folded": flash_grid_steps(nq, "folded"),
            "step_ratio": flash_grid_steps(nq, "bb")
            / flash_grid_steps(nq, "folded"),
        })
    return rows


ATTN_KINDS = (("bb", "flash-bb"), ("folded", "flash-folded"),
              ("chunked", "chunked"))


def serving_rows(quick: bool = False):
    """ATTN rows: serve-workload tokens/s per attention executor kind.

    Runs ``launch/serve.py``'s actual prefill+decode path (reduced
    yi-6b via ``Model``) three times — attention pinned to the flash
    kernel's bb and folded schedules and to the chunked XLA path — and
    records batched tokens/s for prefill and decode.  ``grid_steps``
    carries heads x flash_grid_steps at the shape the dispatch would
    launch (chunked is charged the folded walk it replaces), and
    ``step_ratio`` the bb/folded grid-step quotient at that tile count.
    Full mode adds an attention-only trio at nq=16 where the quotient
    reaches ~1.9 (→ 2 as nq grows — the paper's speedup bound).
    """
    from repro.autotune import choose_attn_impl
    from repro.configs.ALL import REDUCED
    from repro.kernels.policy import default_interpret
    from repro.models.model import Model

    interpret = default_interpret()
    cfg0 = REDUCED["yi-6b"]().replace(
        act_dtype="float32", param_dtype="float32", remat="none"
    )
    b, s, gen = 4, 64, (8 if quick else 24)
    dec = choose_attn_impl(s, cfg0.n_heads, cfg0.hd)
    block = dec.block_q or 32
    nq = s // block
    ratio = flash_grid_steps(nq, "bb") / flash_grid_steps(nq, "folded")
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (b, s), 0, cfg0.vocab)
    rows = []
    for kind, impl in ATTN_KINDS:
        cfg = cfg0.replace(attention_impl=impl)
        model = Model(cfg)
        params = model.init(key)
        batch = {"tokens": tokens}
        prefill = jax.jit(lambda p, bt, model=model: model.prefill(p, bt))
        logits, caches = jax.block_until_ready(prefill(params, batch))
        t0 = time.perf_counter()
        reps = 2 if quick else 3
        for _ in range(reps):
            logits, caches = prefill(params, batch)
            jax.block_until_ready(logits)
        prefill_s = (time.perf_counter() - t0) / reps
        decode = jax.jit(
            lambda p, c, bt, model=model: model.decode(p, c, bt)
        )
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        step0 = {"tokens": tok, "pos": jnp.full((b,), s, jnp.int32)}
        jax.block_until_ready(decode(params, caches, step0)[0])
        t0 = time.perf_counter()
        for i in range(gen):
            sb = {"tokens": tok, "pos": jnp.full((b,), s + i, jnp.int32)}
            lg, _ = decode(params, caches, sb)
            tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        jax.block_until_ready(tok)
        decode_s = time.perf_counter() - t0
        rows.append({
            "test": "ATTN", "map": kind, "m": 2, "n": nq,
            "grid_steps": cfg.n_heads * flash_grid_steps(
                nq, "bb" if kind == "bb" else "folded"
            ),
            "seq": s, "batch": b, "heads": cfg.n_heads,
            "head_dim": cfg.hd, "step_ratio": ratio,
            "tok_s_prefill": b * s / prefill_s,
            "tok_s_decode": b * gen / decode_s,
            "us_per_call": prefill_s * 1e6,
            "compiled": kind == "chunked" or not interpret,
        })
    if not quick:
        rows.extend(_attn_scale_rows(interpret))
    return rows


def _attn_scale_rows(interpret: bool):
    """Attention-only ATTN trio at nq=16: step_ratio 256/136 ~ 1.9."""
    from repro.models.attention import simplex_attention

    b, h, s, d, block = 1, 4, 2048, 64, 128
    nq = s // block
    ratio = flash_grid_steps(nq, "bb") / flash_grid_steps(nq, "folded")
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, h, s, d), jnp.float32)
    rows = []
    for kind, impl in ATTN_KINDS:
        if kind != "chunked":
            from repro.kernels.flash_attention import flash_attention

            f = jax.jit(lambda q, k, v, kind=kind: flash_attention(
                q, k, v, kind=kind, block_q=block, block_kv=block
            ))
        else:
            f = jax.jit(lambda q, k, v: simplex_attention(
                q, k, v, impl="chunked", chunk=block
            ))
        us = _time(f, q, k, v, reps=2)
        rows.append({
            "test": "ATTN", "map": kind, "m": 2, "n": nq,
            "grid_steps": h * flash_grid_steps(
                nq, "bb" if kind == "bb" else "folded"
            ),
            "seq": s, "batch": b, "heads": h, "head_dim": d,
            "step_ratio": ratio,
            "tok_s_prefill": b * s / (us * 1e-6),
            "us_per_call": us,
            "compiled": kind == "chunked" or not interpret,
        })
    return rows


def main():
    rows = run()
    print("shape,bb_us,folded_us,wall_speedup,steps_bb,steps_folded,step_ratio")
    for r in rows:
        print(f"{r['shape']},{r['bb_us']:.0f},{r['folded_us']:.0f},"
              f"{r['wall_speedup']:.2f},{r['grid_steps_bb']},"
              f"{r['grid_steps_folded']},{r['step_ratio']:.2f}")
    return rows


if __name__ == "__main__":
    main()
