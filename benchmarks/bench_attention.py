"""Beyond-paper table: the simplex schedule applied to causal attention.

Measures the *compiled XLA* path (repro.models.attention) — real matmul
work on this host, no interpreter overhead: the folded schedule runs
~tri(n)/n^2 of BB's block FLOPs, so wall-clock speedup should approach
2x as nq grows.  Also reports the Pallas kernel's grid-step counts
(the TPU-structural quantity) per (seq, block) shape.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_grid_steps
from repro.models.attention import chunked_causal_attention


def _time(f, *args, reps=3):
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    for (b, h, s, d, chunk) in [
        (1, 4, 1024, 64, 128),
        (1, 4, 2048, 64, 256),
        (1, 8, 4096, 64, 256),
    ]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, h, s, d), dtype=jnp.float32)
        k = jax.random.normal(ks[1], (b, h, s, d), dtype=jnp.float32)
        v = jax.random.normal(ks[2], (b, h, s, d), dtype=jnp.float32)
        nq = s // chunk
        us = {}
        for sched in ["bb", "folded"]:
            f = jax.jit(
                lambda q, k, v, sched=sched: chunked_causal_attention(
                    q, k, v, chunk=chunk, schedule=sched
                )
            )
            us[sched] = _time(f, q, k, v)
        rows.append({
            "shape": f"B{b}H{h}S{s}D{d}/c{chunk}",
            "bb_us": us["bb"],
            "folded_us": us["folded"],
            "wall_speedup": us["bb"] / us["folded"],
            "grid_steps_bb": flash_grid_steps(nq, "bb"),
            "grid_steps_folded": flash_grid_steps(nq, "folded"),
            "step_ratio": flash_grid_steps(nq, "bb")
            / flash_grid_steps(nq, "folded"),
        })
    return rows


def main():
    rows = run()
    print("shape,bb_us,folded_us,wall_speedup,steps_bb,steps_folded,step_ratio")
    for r in rows:
        print(f"{r['shape']},{r['bb_us']:.0f},{r['folded_us']:.0f},"
              f"{r['wall_speedup']:.2f},{r['grid_steps_bb']},"
              f"{r['grid_steps_folded']},{r['step_ratio']:.2f}")
    return rows


if __name__ == "__main__":
    main()
