"""Paper Fig. 10 — 2-simplex tests: MAP / ACCUM / EDM / CA2D for
{H(omega), RB, lambda, BB}.

Two measurements per (test x map):
  * parallel-space ratio — grid steps the schedule launches vs BB; this
    is hardware-independent and is what the paper's MAP test isolates
    (its theoretical 2x);
  * wall-clock of the jitted kernel on this host (interpret-mode Pallas:
    per-step interpreter cost makes wall time track grid steps; the XLA
    attention benchmark below gives a compiled-speed counterpart).

The lambda map additionally reproduces the paper's FP32 precision
failure (§3/§5.2: exact only in a bounded range without integer
correction).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import SimplexSchedule
from repro.core.maps_baseline import lambda_map2_raw
from repro.kernels import ref as R
from repro.kernels import engine as K


def _time(f, *args, reps=3):
    f(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(n: int = 256, rho: int = 16):
    nb = n // rho
    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (n, n), 0, 100).astype(jnp.int32)
    p = jax.random.normal(key, (n, 64), dtype=jnp.float32)
    ca = (jax.random.uniform(key, (n, n)) < 0.4).astype(jnp.int32)
    ca = ca * R.tril_mask(n, jnp.int32)

    import functools

    tests = {
        "MAP": lambda kind: functools.partial(K.map_table, nb, m=2, kind=kind),
        "ACCUM": lambda kind: functools.partial(K.accum, x, rho=rho, kind=kind),
        "EDM": lambda kind: functools.partial(K.edm2d, p, rho=rho, kind=kind),
        "CA2D": lambda kind: functools.partial(K.ca, ca, rho=rho, kind=kind),
    }
    for tname, mk in tests.items():
        bb_steps = SimplexSchedule(2, nb, "bb").steps
        bb_us = _time(jax.jit(mk("bb")))
        for kind in ["hmap", "rb", "bb"]:
            sched = SimplexSchedule(2, nb, kind)
            us = bb_us if kind == "bb" else _time(jax.jit(mk(kind)))
            rows.append({
                "test": tname, "map": kind, "m": 2, "n": n, "rho": rho,
                "grid_steps": sched.steps,
                "waste": sched.waste(),
                "space_speedup_vs_bb": bb_steps / sched.steps,
                "us_per_call": us,
                "wall_speedup_vs_bb": bb_us / us,
            })
    return rows


def lambda_precision_probe():
    """The uncorrected FP32 lambda map fails beyond a bounded n — the
    paper's motivation for the root-free H map."""
    bad_n = None
    for n in [1024, 4096, 16384, 65536, 262144, 1 << 21, 1 << 23]:
        total = n * (n + 1) // 2
        w = np.arange(total - 64, total, dtype=np.int64)
        xx, yy = lambda_map2_raw(w, dtype=np.float32)
        ok = np.all((xx >= 0) & (xx <= yy)) and np.array_equal(
            yy * (yy + 1) // 2 + xx, w
        )
        if not ok:
            bad_n = n
            break
    return {"fp32_lambda_first_failure_n": bad_n}


def main():
    rows = run()
    print("test,map,grid_steps,space_speedup_vs_bb,us_per_call,wall_speedup_vs_bb")
    for r in rows:
        print(f"{r['test']},{r['map']},{r['grid_steps']},"
              f"{r['space_speedup_vs_bb']:.3f},{r['us_per_call']:.0f},"
              f"{r['wall_speedup_vs_bb']:.2f}")
    print("lambda_fp32:", lambda_precision_probe())
    return rows


if __name__ == "__main__":
    main()
