"""Paper Fig. 12/15 — energy efficiency (EPS/W), MODELED.

This container has no power rails; energy is modeled, not measured
(DESIGN.md §2).  Model, stated explicitly:

    P(map) = P_idle + P_dyn * occupancy,   occupancy = useful/launched
    E      = P * T,   T proportional to launched grid steps
    EPS/W  = elements / (T * P)

with v5e-flavoured constants P_idle = 60 W, P_dyn = 140 W (TDP ~200 W).
The paper's qualitative claim this reproduces: H draws *higher* power
than BB (full occupancy) but finishes sooner, netting the best EPS/W —
under any monotone (P_idle, P_dyn), occupancy-1 maps dominate EPS/W
because T shrinks faster than P grows.  The launched-work ratios
underneath are hardware-independent.
"""

from __future__ import annotations

from repro.core.schedule import grid_steps
from repro.core.simplex import tet, tri

P_IDLE, P_DYN = 60.0, 140.0


def _row(test, kind, launched, useful, elements):
    occ = useful / launched
    t = float(launched)  # time units ~ grid steps
    p = P_IDLE + P_DYN * occ
    eps_w = elements / (t * p)
    return {
        "test": test, "map": kind, "launched": launched,
        "occupancy": occ, "power_model_w": p,
        "energy_model": t * p, "eps_per_w_rel": eps_w,
    }


def run(nb2: int = 256, nb3: int = 64):
    rows = []
    el2, el3 = tri(nb2), tet(nb3)
    for kind in ["hmap", "rb", "bb"]:
        rows.append(_row("2-simplex", kind, grid_steps(nb2, kind), el2, el2))
    for kind in ["table", "octant", "bb"]:
        rows.append(_row("3-simplex", kind, grid_steps(nb3, kind, m=3), el3, el3))
    # normalize eps/w to BB = 1.0 per test
    for test in ("2-simplex", "3-simplex"):
        base = next(r for r in rows if r["test"] == test and r["map"] == "bb")
        for r in rows:
            if r["test"] == test:
                r["eps_per_w_vs_bb"] = r["eps_per_w_rel"] / base["eps_per_w_rel"]
    return rows


def main():
    rows = run()
    print("test,map,launched_steps,occupancy,power_w,eps_per_w_vs_bb")
    for r in rows:
        print(f"{r['test']},{r['map']},{r['launched']},{r['occupancy']:.3f},"
              f"{r['power_model_w']:.0f},{r['eps_per_w_vs_bb']:.2f}")
    return rows


if __name__ == "__main__":
    main()
