"""Paper Fig. 12/15 — energy efficiency (EPS/W), MODELED.

This container has no power rails; energy is modeled, not measured
(DESIGN.md §2).  Model, stated explicitly:

    P(map) = P_idle + P_dyn * occupancy,   occupancy = useful/launched
    E      = P * T,   T proportional to launched grid steps
    EPS/W  = elements / (T * P)

with v5e-flavoured constants P_idle = 60 W, P_dyn = 140 W (TDP ~200 W).
The paper's qualitative claim this reproduces: H draws *higher* power
than BB (full occupancy) but finishes sooner, netting the best EPS/W —
under any monotone (P_idle, P_dyn), occupancy-1 maps dominate EPS/W
because T shrinks faster than P grows.  The launched-work ratios
underneath are hardware-independent.

Occupancy and launched steps come straight from the unified
``SimplexSchedule`` surface (``.steps`` / ``.waste()``), so every
registered kind — including the m=4 recursion and the general-n
composite decomposition — is scored by the same model.
"""

from __future__ import annotations

from repro.core.schedule import SimplexSchedule

P_IDLE, P_DYN = 60.0, 140.0


def _row(test: str, m: int, n: int, kind: str):
    sched = SimplexSchedule(m, n, kind)
    launched, useful = sched.steps, sched.useful
    occ = 1.0 / (1.0 + sched.waste())  # useful/launched, from the schedule
    t = float(launched)  # time units ~ grid steps
    p = P_IDLE + P_DYN * occ
    eps_w = useful / (t * p)
    return {
        "test": test, "map": kind, "m": m, "n": n, "launched": launched,
        "occupancy": occ, "power_model_w": p,
        "energy_model": t * p, "eps_per_w_rel": eps_w,
    }


# (test label, m, n, kinds) — nb=100 exercises the general-n composite
# path (non-pow2, analytical); the m=4 group is the ROADMAP refresh.
GROUPS = [
    ("2-simplex", 2, 256, ["hmap", "rb", "bb"]),
    ("3-simplex", 3, 64, ["table", "octant", "bb"]),
    ("3-simplex-generaln", 3, 100, ["composite", "table", "bb"]),
    ("4-simplex", 4, 16, ["hmap", "table", "bb"]),
    ("4-simplex-generaln", 4, 24, ["composite", "table", "bb"]),
]


def run(groups=GROUPS):
    rows = []
    for test, m, n, kinds in groups:
        for kind in kinds:
            rows.append(_row(test, m, n, kind))
        base = next(r for r in rows if r["test"] == test and r["map"] == "bb")
        for r in rows:
            if r["test"] == test:
                r["eps_per_w_vs_bb"] = r["eps_per_w_rel"] / base["eps_per_w_rel"]
    return rows


def main():
    rows = run()
    print("test,map,m,n,launched_steps,occupancy,power_w,eps_per_w_vs_bb")
    for r in rows:
        print(f"{r['test']},{r['map']},{r['m']},{r['n']},{r['launched']},"
              f"{r['occupancy']:.3f},{r['power_model_w']:.0f},"
              f"{r['eps_per_w_vs_bb']:.2f}")
    return rows


if __name__ == "__main__":
    main()
