"""Paper §6 / Thm 6.2 table — general m-simplex self-similar sets.

For each m: the r=1/2, beta=2 extra-space fraction (Lemma 6.1's
m!/(2^m-2) - 1), the best integer (1/r, beta) found by the Thm 6.2
optimization, its n0 coverage onset, and the resulting parallel-space
speedup vs bounding box (upper bound m!).

Since the unification of the scheduling layer (DESIGN.md §4), each row
also reports the *constructed* map family (1/r, beta) = (2, m) realized
by ``hmap_m_recursive``: its asymptotic alpha and the measured waste of
``SimplexSchedule(m, n, 'hmap')`` at a concrete n — feasibility numbers
vs what the shipped bijection actually achieves."""

from __future__ import annotations

import math

from repro.core.general_m import (
    alpha_extra_space,
    alpha_r_half_beta_2,
    best_r_beta,
    optimize_r_beta,
)
from repro.core.schedule import SimplexSchedule


def run(m_max: int = 8, n_measure: int = 64):
    rows = []
    for m in range(2, m_max + 1):
        cands = optimize_r_beta(m, max_inv_r=10, max_beta=24, n_max=1 << 22)
        best = cands[0] if cands else None
        c_inv_r, c_beta = best_r_beta(m, constructible=True)
        sched = SimplexSchedule(m, n_measure, "hmap")
        rows.append({
            "m": m,
            "alpha_half_2": alpha_r_half_beta_2(m),
            "best_inv_r": best.inv_r if best else None,
            "best_beta": best.beta if best else None,
            "best_alpha": best.alpha if best else None,
            "n0": best.n0 if best else None,
            "speedup_vs_bb": best.speedup if best else None,
            "constructible_inv_r": c_inv_r,
            "constructible_beta": c_beta,
            "constructible_alpha": alpha_extra_space(m, c_inv_r, c_beta),
            "measured_waste": sched.waste(),
            "measured_n": n_measure,
            "measured_speedup_vs_bb": n_measure**m / sched.steps,
            "speedup_upper_bound": float(math.factorial(m)),
        })
    return rows


def main():
    rows = run()
    print("m,alpha(r=1/2,b=2),best_1/r,best_beta,best_alpha,n0,speedup,"
          "ctor_1/r,ctor_beta,ctor_alpha,measured_waste,measured_speedup,"
          "bound_m!")
    for r in rows:
        print(f"{r['m']},{r['alpha_half_2']:.3f},{r['best_inv_r']},"
              f"{r['best_beta']},{r['best_alpha']:.3f},{r['n0']},"
              f"{r['speedup_vs_bb']:.1f},{r['constructible_inv_r']},"
              f"{r['constructible_beta']},{r['constructible_alpha']:.3f},"
              f"{r['measured_waste']:.3f},{r['measured_speedup_vs_bb']:.1f},"
              f"{r['speedup_upper_bound']:.0f}")
    return rows


if __name__ == "__main__":
    main()
