"""Benchmark harness aggregator — one section per paper table/figure.

  Fig. 10  -> bench_maps_2simplex   (2-simplex: MAP/ACCUM/EDM/CA2D)
  Fig. 13  -> bench_maps_3simplex   (3-simplex: MAP3D/ACCUM3D/CA3D)
  Fig12/15 -> bench_energy          (EPS/W, modeled — DESIGN.md §2)
  §6/Thm6.2-> bench_general_m       ((r, beta) optimization table)
  beyond   -> bench_attention       (folded-simplex causal attention)

Prints ``name,us_per_call,derived`` CSV lines per benchmark plus the
full per-table CSVs, and writes ``BENCH_maps.json`` — the per-(kind, m,
n) steps/waste/wall-time artifact future PRs diff their perf trajectory
against.  Roofline tables come from the dry-run artifacts (see
EXPERIMENTS.md §Roofline), not from this harness.
"""

from __future__ import annotations

import json
import math
import os
import time


def _map_rows_md(m: int = 4, n: int = 16, rho: int = 2):
    """General-m section of the artifact: the m>=4 schedules plus a
    wall-clock of the accum_md kernel they drive (interpret mode)."""
    import jax
    import jax.numpy as jnp

    from repro.core.schedule import SimplexSchedule, registered_kinds
    from repro.kernels import simplex_kernels as K

    nb = n // rho
    x = jax.random.randint(jax.random.PRNGKey(0), (n,) * m, 0, 50).astype(
        jnp.int32
    )
    rows = []
    bb_steps = SimplexSchedule(m, nb, "bb").steps
    reps = 3
    for kind in registered_kinds(m):
        sched = SimplexSchedule(m, nb, kind)
        f = jax.jit(lambda kind=kind: K.accum_md(x, rho=rho, kind=kind))
        jax.block_until_ready(f())  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(f())
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append({
            "test": f"ACCUM{m}D", "map": kind, "m": m, "n": n,
            "grid_steps": sched.steps, "waste": sched.waste(),
            "space_speedup_vs_bb": bb_steps / sched.steps,
            "us_per_call": us,
        })
    return rows


def _composite_rows():
    """Composite-vs-table at non-pow2 n, m in {2, 3, 4} (DESIGN.md §4.2).

    Two facts per (m, n): the parallel-space cost (grid_steps/waste — the
    composite pays a bounded analytical premium, the table walk is exact)
    and the HOST-side schedule-construction wall time (us_per_call) — the
    table kind pays the O(V) enumeration, the composite O(pieces).  The
    n ladder quadruples per m so the artifact shows the table build time
    scaling ~V while the composite stays flat.
    """
    from repro.core.schedule import SimplexSchedule

    ladders = {2: [24, 96, 384, 1536], 3: [24, 96, 192], 4: [24, 48]}
    rows = []
    for m, ns in ladders.items():
        for n in ns:
            for kind in ("composite", "table"):
                t0 = time.perf_counter()
                sched = SimplexSchedule(m, n, kind)
                sched.prefetch  # force the table build (lazy; None for composite)
                build_us = (time.perf_counter() - t0) * 1e6
                rows.append({
                    "test": f"SCHED_BUILD{m}D", "map": kind, "m": m, "n": n,
                    "grid_steps": sched.steps, "waste": sched.waste(),
                    "us_per_call": build_us,
                })
    return rows


def write_maps_artifact(rows, path: str = "BENCH_maps.json") -> str:
    """Persist steps/waste/wall-time per (kind, m, n) for perf tracking."""
    artifact = {
        "schema": "bench-maps/v1",
        "rows": [
            {
                "test": r.get("test"),
                "map": r.get("map"),
                "m": r.get("m"),
                "n": r.get("n"),
                "grid_steps": r.get("grid_steps"),
                "waste": r.get("waste"),
                "us_per_call": (
                    None
                    if r.get("us_per_call") is None
                    or (isinstance(r.get("us_per_call"), float)
                        and math.isnan(r["us_per_call"]))
                    else r["us_per_call"]
                ),
            }
            for r in rows
            if "grid_steps" in r
        ],
    }
    with open(path, "w") as f:
        json.dump(artifact, f, indent=1)
    return os.path.abspath(path)


def main() -> None:
    from . import (
        bench_attention,
        bench_energy,
        bench_general_m,
        bench_maps_2simplex,
        bench_maps_3simplex,
    )

    t0 = time.time()
    print("# ==== Fig.10: 2-simplex maps ====")
    r2 = bench_maps_2simplex.main()
    print("# ==== Fig.13: 3-simplex maps ====")
    r3 = bench_maps_3simplex.main()
    print("# ==== beyond-paper: general-m (m=4) schedules ====")
    rm = _map_rows_md()
    for r in rm:
        print(f"{r['test']},{r['map']},{r['grid_steps']},{r['waste']:.3f},"
              f"{r['us_per_call']:.0f}")
    print("# ==== §4.2: composite vs table at non-pow2 n (host build) ====")
    rc = _composite_rows()
    for r in rc:
        print(f"{r['test']},{r['map']},n={r['n']},{r['grid_steps']},"
              f"{r['waste']:.3f},build_us={r['us_per_call']:.0f}")
    print("# ==== Fig.12/15: energy (modeled) ====")
    re = bench_energy.main()
    print("# ==== §6: general-m (r,beta) ====")
    rg = bench_general_m.main()
    print("# ==== beyond-paper: folded causal attention ====")
    ra = bench_attention.main()

    path = write_maps_artifact(r2 + r3 + rm + rc)
    print(f"# wrote {path}")

    print("# ==== summary: name,us_per_call,derived ====")
    for r in r2:
        print(f"fig10/{r['test']}/{r['map']},{r['us_per_call']:.0f},"
              f"space_speedup={r['space_speedup_vs_bb']:.3f}")
    for r in r3:
        us = r["us_per_call"]
        print(f"fig13/{r['test']}/{r['map']},"
              f"{us if not math.isnan(us) else 0:.0f},"
              f"space_speedup={r['space_speedup_vs_bb']:.3f}")
    for r in rm:
        print(f"md/{r['test']}/{r['map']},{r['us_per_call']:.0f},"
              f"space_speedup={r['space_speedup_vs_bb']:.3f}")
    for r in rc:
        print(f"sched/{r['test']}/{r['map']}/n={r['n']},"
              f"{r['us_per_call']:.0f},waste={r['waste']:.3f}")
    for r in re:
        print(f"fig12/{r['test']}/{r['map']},0,"
              f"eps_per_w_vs_bb={r['eps_per_w_vs_bb']:.2f}")
    for r in rg:
        print(f"sec6/m={r['m']},0,speedup={r['speedup_vs_bb']:.1f}")
    for r in ra:
        print(f"attn/{r['shape']},{r['folded_us']:.0f},"
              f"wall_speedup={r['wall_speedup']:.2f}")
    print(f"# total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
