"""Benchmark harness aggregator — one section per paper table/figure.

  Fig. 10  -> bench_maps_2simplex   (2-simplex: MAP/ACCUM/EDM/CA2D)
  Fig. 13  -> bench_maps_3simplex   (3-simplex: MAP3D/ACCUM3D/CA3D)
  Fig12/15 -> bench_energy          (EPS/W, modeled — DESIGN.md §2)
  §6/Thm6.2-> bench_general_m       ((r, beta) optimization table)
  beyond   -> bench_attention       (folded-simplex causal attention)

Prints ``name,us_per_call,derived`` CSV lines per benchmark plus the
full per-table CSVs, and writes ``BENCH_maps.json`` — the per-(kind, m,
n) steps/waste/wall-time artifact future PRs diff their perf trajectory
against.  Roofline tables come from the dry-run artifacts (see
EXPERIMENTS.md §Roofline), not from this harness.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import time


def _map_rows_md(m: int = 4, n: int = 16, rho: int = 2):
    """General-m section of the artifact: the m>=4 schedules plus a
    wall-clock of the accum_md kernel they drive (interpret mode)."""
    import jax
    import jax.numpy as jnp

    from repro.core.schedule import SimplexSchedule, registered_kinds
    from repro.kernels import engine as Eng

    nb = n // rho
    x = jax.random.randint(jax.random.PRNGKey(0), (n,) * m, 0, 50).astype(
        jnp.int32
    )
    rows = []
    bb_steps = SimplexSchedule(m, nb, "bb").steps
    reps = 3
    for kind in registered_kinds(m):
        sched = SimplexSchedule(m, nb, kind)
        f = jax.jit(lambda kind=kind: Eng.accum_md(x, rho=rho, kind=kind))
        jax.block_until_ready(f())  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(f())
        us = (time.perf_counter() - t0) / reps * 1e6
        rows.append({
            "test": f"ACCUM{m}D", "map": kind, "m": m, "n": n,
            "grid_steps": sched.steps, "waste": sched.waste(),
            "space_speedup_vs_bb": bb_steps / sched.steps,
            "us_per_call": us,
        })
    return rows


def _composite_rows():
    """Composite-vs-table at non-pow2 n, m in {2, 3, 4} (DESIGN.md §4.2).

    Two facts per (m, n): the parallel-space cost (grid_steps/waste — the
    composite pays a bounded analytical premium, the table walk is exact)
    and the HOST-side schedule-construction wall time (us_per_call) — the
    table kind pays the O(V) enumeration, the composite O(pieces).  The
    n ladder quadruples per m so the artifact shows the table build time
    scaling ~V while the composite stays flat.
    """
    from repro.core.schedule import SimplexSchedule

    ladders = {2: [24, 96, 384, 1536], 3: [24, 96, 192], 4: [24, 48]}
    rows = []
    for m, ns in ladders.items():
        for n in ns:
            for kind in ("composite", "table"):
                t0 = time.perf_counter()
                sched = SimplexSchedule(m, n, kind)
                sched.prefetch  # force the table build (lazy; None for composite)
                build_us = (time.perf_counter() - t0) * 1e6
                rows.append({
                    "test": f"SCHED_BUILD{m}D", "map": kind, "m": m, "n": n,
                    "grid_steps": sched.steps, "waste": sched.waste(),
                    "us_per_call": build_us,
                })
    return rows


def _compiled_rows(quick: bool = False):
    """Compiled-execution section: ``compiled: true`` rows for ACCUM
    (m=2, n=256) and ACCUM3D.  The launched kind is autotuner-selected
    (``repro.autotune.choose_kind`` — the harness never hand-picks a
    schedule, the winner row carries ``autotune_source``), and every
    *candidate* kind is additionally timed and recorded so the tuner's
    measured ranking has symmetric evidence on the next run (it only
    trusts measurements that cover all candidates).  On this host
    "compiled" means the fused-XLA executors of ``kernels/compiled.py``
    (one jit program for the whole schedule walk); on TPU/GPU the same
    entry points lower as non-interpret Pallas.  Each output is
    parity-checked against the pure-numpy truth before its row is
    recorded — a wrong compiled walk aborts the run."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.autotune import candidate_kinds, choose_kind
    from repro.core.schedule import SimplexSchedule
    from repro.kernels.compiled import accum2d_compiled, accum3d_compiled
    from repro.kernels.policy import backend_name

    backend = backend_name()
    reps = 3 if quick else 10
    rows = []

    def _timed(f, *args):
        out = jax.block_until_ready(f(*args))  # warmup/compile
        t0 = time.perf_counter()
        for _ in range(reps):
            out = jax.block_until_ready(f(*args))
        return out, (time.perf_counter() - t0) / reps * 1e6

    def _section(test, m, n, rho, runner, x, want):
        decision = choose_kind(m, n // rho, backend=backend)
        for kind in candidate_kinds(m, n // rho):
            out, us = _timed(runner, x, rho, kind)
            if not np.array_equal(np.asarray(out), want):
                raise SystemExit(f"compiled {test} parity FAILED ({kind})")
            sched = SimplexSchedule(m, n // rho, kind)
            row = {
                "test": test, "map": kind, "m": m, "n": n, "rho": rho,
                "grid_steps": sched.steps, "waste": sched.waste(),
                "us_per_call": us, "compiled": True,
            }
            if kind == decision.kind:
                row["autotune_source"] = decision.source
            rows.append(row)

    # -- ACCUM, m=2, n=256 --------------------------------------------
    n2, rho2 = 256, 16
    x2 = jax.random.randint(jax.random.PRNGKey(0), (n2, n2), 0, 100)
    x2 = x2.astype(jnp.int32)
    want2 = np.asarray(x2) + np.tri(n2, dtype=np.int32)
    _section("ACCUM", 2, n2, rho2, accum2d_compiled, x2, want2)

    # -- ACCUM3D ------------------------------------------------------
    n3, rho3 = (32, 4) if quick else (64, 4)
    x3 = jax.random.randint(jax.random.PRNGKey(1), (n3,) * 3, 0, 50)
    x3 = x3.astype(jnp.int32)
    ii = np.arange(n3)
    simplex = (
        ii[:, None, None] + ii[None, :, None] + ii[None, None, :]
    ) < n3
    want3 = np.asarray(x3) + simplex.astype(np.int32)
    _section("ACCUM3D", 3, n3, rho3, accum3d_compiled, x3, want3)
    return rows


def _engine_parity_rows(quick: bool = False):
    """ENGINE_PARITY section: the differential harness as artifact rows.

    For each registered engine body x dimension x schedule kind, run the
    engine-built kernel and record ``max_abs_err`` against the strongest
    available baseline — the frozen hand-rolled kernel in
    ``kernels/legacy.py`` where one exists (bit-parity expected, so the
    recorded err must be 0), else the ``kernels/ref.py`` numpy oracle on
    the domain (float-tolerance for the m >= 3 EDM bodies).  A non-zero
    integer-body error aborts the run: a silently wrong engine must
    never produce a plausible-looking benchmark artifact.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import engine as Eng
    from repro.kernels import legacy as Leg
    from repro.kernels import ref as Ref

    kinds = {
        2: ["hmap", "bb"] if quick else ["hmap", "rb", "bb", "composite"],
        3: ["hmap", "table"] if quick else
           ["hmap", "octant", "bb", "table", "composite"],
        4: ["hmap", "composite"] if quick else
           ["hmap", "bb", "table", "composite"],
    }
    sizes = {2: (16, 4), 3: (8, 2), 4: (8, 2)}
    legacy_2d = ("hmap", "rb", "bb")
    rows = []
    for m, (n, rho) in sizes.items():
        msk = np.asarray(Ref.simplex_mask(m, n))
        x = jnp.asarray((np.arange(n**m, dtype=np.int64) % 97).astype(
            np.int32).reshape((n,) * m))
        p = jax.random.normal(jax.random.PRNGKey(m), (n, 3), jnp.float32)
        s = (jax.random.uniform(jax.random.PRNGKey(m + 8), (n,) * m)
             < 0.4).astype(jnp.int32) * Ref.simplex_mask(m, n, jnp.int32)

        def _cases(kind):
            has_legacy = m != 2 or kind in legacy_2d
            yield ("accum", Eng.accum(x, rho=rho, kind=kind),
                   ({2: Leg.accum2d, 3: Leg.accum3d}.get(m, Leg.accum_md)(
                       x, rho=rho, kind=kind) if has_legacy
                    else jnp.where(Ref.simplex_mask(m, n), Ref.accum_md(x),
                                   x)),
                   True)
            edm = (Eng.edm2d(p, rho=rho, kind=kind) if m == 2
                   else Eng.edm_md(p, m, rho=rho, kind=kind))
            edm_base = (Leg.edm2d(p, rho=rho, kind=kind)
                        if m == 2 and has_legacy else Ref.edm_md(p, m))
            yield ("edm", edm, edm_base, m == 2 and has_legacy)
            ca = Eng.ca(s, rho=rho, kind=kind)
            if m in (2, 3) and has_legacy:
                ca_base = {2: Leg.ca2d, 3: Leg.ca3d}[m](s, rho=rho, kind=kind)
                exact = True
            elif m == 2:
                # no legacy baseline for this kind; periodic 2-simplex oracle
                ca_base = jnp.where(Ref.simplex_mask(m, n),
                                    Ref.ca2d_step(s), s)
                exact = True
            else:
                ca_base = jnp.where(Ref.simplex_mask(m, n),
                                    Ref.ca_md_step(s), s)
                exact = True
            yield ("ca", ca, ca_base, exact)

        for kind in kinds[m]:
            sched_steps = Eng.grid_steps(n // rho, kind, m=m)
            for body, got, base, exact in _cases(kind):
                err = float(np.max(np.abs(
                    np.asarray(got, dtype=np.float64)
                    - np.asarray(base, dtype=np.float64)
                )))
                if exact and err != 0.0:
                    raise SystemExit(
                        f"ENGINE_PARITY FAILED: body={body} m={m} "
                        f"kind={kind} max_abs_err={err}"
                    )
                if not exact and err > 1e-4:
                    raise SystemExit(
                        f"ENGINE_PARITY FAILED (tolerance): body={body} "
                        f"m={m} kind={kind} max_abs_err={err}"
                    )
                rows.append({
                    "test": "ENGINE_PARITY", "body": body, "map": kind,
                    "m": m, "n": n, "grid_steps": sched_steps,
                    "max_abs_err": err,
                })
    return rows


def _shard_rows(quick: bool = False):
    """SHARD_SKEW section: fold-partition balance + sharded bit-exactness.

    For each (m, n, kind, shards) cell, record the fold partition's
    block-volume skew (max/mean shard steps — bounded by 1 + k/S, the
    information-theoretic optimum) next to the naive equal-thickness
    slab baseline (~m x), plus ``bit_exact`` for the cells where the
    sharded CA is actually executed against the single-device engine
    (DESIGN.md §7).  A sharded-CA mismatch aborts the run.  Runs the
    same under 1 or k devices — with >= ``shards`` devices the engine
    launches are placed round-robin on a real mesh
    (``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in CI).
    """
    import jax
    import numpy as np

    from repro.core.schedule import SimplexSchedule, resolve_kind
    from repro.distributed.simplex_sharding import (
        shard_mesh, shard_skew, sharded_ca, slab_skew,
    )
    from repro.kernels import ref as Ref
    from repro.kernels.engine import default_rho
    from repro.kernels.ops import simplex_ca2d, simplex_ca_md

    # skew cells are analytic (O(1)); CA bit-exactness runs on the
    # moderate cells where interpret-mode Pallas stays fast.
    skew_ns = {2: [64, 128, 256] if quick else [64, 128, 192, 256],
               3: [16, 32, 64] if quick else [16, 32, 64, 128]}
    ca_cells = ({(2, 64), (3, 16), (3, 32)} if quick
                else {(2, 64), (3, 16), (3, 32)})
    rows = []
    for m, ns in skew_ns.items():
        rho = default_rho(m)
        for n in ns:
            nb = n // rho
            kind = resolve_kind(m, nb, "hmap" if m == 2 else "table")
            sched = SimplexSchedule(m, nb, kind)
            for k in (2, 4, 8):
                if k > sched.steps:
                    continue
                sk = shard_skew(sched, k)
                if sk > 1.05:
                    # ceil(S/k)/(S/k) is the optimum for S steps; tiny
                    # cells (S < ~20k) cannot meet the 1.05 regime with
                    # ANY partition — log, don't record.
                    print(f"# SHARD_SKEW skip m={m} n={n} k={k}: "
                          f"S={sched.steps} too small (optimal skew "
                          f"{sk:.3f} > 1.05)")
                    continue
                row = {
                    "test": "SHARD_SKEW", "map": kind, "m": m, "n": n,
                    "grid_steps": sched.steps, "shards": k,
                    "skew": sk,
                }
                if k <= nb:  # the slab baseline needs k nonempty layers
                    row["slab_skew"] = slab_skew(m, nb, k)
                if (m, n) in ca_cells:
                    mesh = (shard_mesh(k)
                            if jax.device_count() >= k else None)
                    rng = np.random.default_rng(m * 10 + k)
                    s = (rng.random((n,) * m) < 0.4).astype(np.int32)
                    s = np.where(np.asarray(Ref.simplex_mask(m, n)), s, 0)
                    s = s.astype(np.int32)
                    single = (simplex_ca2d if m == 2 else simplex_ca_md)(
                        s, kind=kind
                    )
                    shd = sharded_ca(s, k, kind=kind, mesh=mesh)
                    exact = bool(np.array_equal(
                        np.asarray(single), np.asarray(shd)
                    ))
                    if not exact:
                        raise SystemExit(
                            f"SHARD_SKEW bit-exactness FAILED: m={m} "
                            f"n={n} kind={kind} shards={k}"
                        )
                    row["bit_exact"] = exact
                    row["devices"] = jax.device_count()
                rows.append(row)
    return rows


def write_maps_artifact(rows, path: str = "BENCH_maps.json") -> str:
    """Persist steps/waste/wall-time per (kind, m, n) for perf tracking.

    Schema bench-maps/v2: every row additionally records the backend it
    ran on, the JAX version, and whether it went down the compiled path
    (fused-XLA / non-interpret Pallas) or the interpret emulator — so
    the autotuner and future-PR perf diffs never mix the two regimes.
    """
    import jax

    from repro.kernels.policy import backend_name

    backend = backend_name()
    jax_version = jax.__version__
    artifact = {
        "schema": "bench-maps/v2",
        "rows": [
            {
                "test": r.get("test"),
                "map": r.get("map"),
                "m": r.get("m"),
                "n": r.get("n"),
                "grid_steps": r.get("grid_steps"),
                "waste": r.get("waste"),
                "us_per_call": (
                    None
                    if r.get("us_per_call") is None
                    or (isinstance(r.get("us_per_call"), float)
                        and math.isnan(r["us_per_call"]))
                    else r["us_per_call"]
                ),
                "backend": backend,
                "jax_version": jax_version,
                "compiled": bool(r.get("compiled", False)),
                **(
                    {"autotune_source": r["autotune_source"]}
                    if "autotune_source" in r
                    else {}
                ),
                **({"body": r["body"]} if "body" in r else {}),
                **(
                    {"max_abs_err": r["max_abs_err"]}
                    if "max_abs_err" in r
                    else {}
                ),
                **{
                    key: r[key]
                    for key in ("shards", "skew", "slab_skew",
                                "bit_exact", "devices",
                                "seq", "batch", "heads", "head_dim",
                                "step_ratio", "tok_s_prefill",
                                "tok_s_decode")
                    if key in r
                },
            }
            for r in rows
            if "grid_steps" in r
        ],
    }
    with open(path, "w") as f:
        json.dump(artifact, f, indent=1)
    return os.path.abspath(path)


def validate_artifact(path: str) -> None:
    """Fail (SystemExit) unless the artifact is well-formed v2 with at
    least one compiled row — the schema gate the CI smoke job runs.

    When ATTN rows are present (the serving metric — DESIGN.md §8),
    additionally require all three executor kinds {bb, folded, chunked}
    with positive prefill tokens/s per kind.
    """
    with open(path) as f:
        artifact = json.load(f)
    if artifact.get("schema") != "bench-maps/v2":
        raise SystemExit(f"bad schema: {artifact.get('schema')!r}")
    rows = artifact.get("rows", [])
    required = ("test", "map", "m", "n", "grid_steps", "backend",
                "jax_version", "compiled")
    for r in rows:
        missing = [k for k in required if k not in r]
        if missing:
            raise SystemExit(f"row missing {missing}: {r}")
    if not any(r["compiled"] for r in rows):
        raise SystemExit("no compiled rows in artifact")
    attn = [r for r in rows if r["test"] == "ATTN"]
    if attn:
        kinds = {r["map"] for r in attn}
        if not {"bb", "folded", "chunked"} <= kinds:
            raise SystemExit(f"ATTN rows missing kinds: {sorted(kinds)}")
        for r in attn:
            if not r.get("tok_s_prefill", 0) > 0:
                raise SystemExit(f"ATTN row without tokens/s: {r}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--quick", action="store_true",
        help="CI smoke mode: compiled rows + schedule builds only "
             "(skips the interpret-mode kernel sweeps), then validates "
             "the written artifact",
    )
    ap.add_argument(
        "--out", default=None,
        help="artifact path (default BENCH_maps.json; "
             "BENCH_maps.quick.json under --quick)",
    )
    ns = ap.parse_args(argv)
    out = ns.out or ("BENCH_maps.quick.json" if ns.quick else
                     "BENCH_maps.json")

    t0 = time.time()
    if ns.quick:
        print("# ==== compiled execution (autotuned kinds) ====")
        rcomp = _compiled_rows(quick=True)
        for r in rcomp:
            print(f"{r['test']},{r['map']},{r['grid_steps']},"
                  f"{r['us_per_call']:.0f},src={r.get('autotune_source', '-')}")
        print("# ==== §4.2: composite vs table (host build) ====")
        rc = _composite_rows()
        print("# ==== engine parity (differential: engine vs legacy/ref) ====")
        rp = _engine_parity_rows(quick=True)
        for r in rp:
            print(f"{r['test']},{r['body']},{r['map']},m={r['m']},"
                  f"err={r['max_abs_err']:.2e}")
        print("# ==== §7: sharded simplex execution (fold skew) ====")
        rs = _shard_rows(quick=True)
        for r in rs:
            print(f"{r['test']},{r['map']},m={r['m']},n={r['n']},"
                  f"k={r['shards']},skew={r['skew']:.4f},"
                  f"slab={r.get('slab_skew', float('nan')):.3f},"
                  f"bit_exact={r.get('bit_exact', '-')}")
        print("# ==== §8: serving attention (tokens/s per executor) ====")
        from . import bench_attention
        ratt = bench_attention.serving_rows(quick=True)
        for r in ratt:
            print(f"{r['test']},{r['map']},steps={r['grid_steps']},"
                  f"tok_s_prefill={r['tok_s_prefill']:.0f},"
                  f"tok_s_decode={r.get('tok_s_decode', float('nan')):.0f},"
                  f"step_ratio={r['step_ratio']:.2f}")
        path = write_maps_artifact(rcomp + rc + rp + rs + ratt, path=out)
        validate_artifact(path)
        print(f"# wrote + validated {path}")
        print(f"# total {time.time()-t0:.0f}s")
        return

    from . import (
        bench_attention,
        bench_energy,
        bench_general_m,
        bench_maps_2simplex,
        bench_maps_3simplex,
    )

    print("# ==== Fig.10: 2-simplex maps ====")
    r2 = bench_maps_2simplex.main()
    print("# ==== Fig.13: 3-simplex maps ====")
    r3 = bench_maps_3simplex.main()
    print("# ==== beyond-paper: general-m (m=4) schedules ====")
    rm = _map_rows_md()
    for r in rm:
        print(f"{r['test']},{r['map']},{r['grid_steps']},{r['waste']:.3f},"
              f"{r['us_per_call']:.0f}")
    print("# ==== §4.2: composite vs table at non-pow2 n (host build) ====")
    rc = _composite_rows()
    for r in rc:
        print(f"{r['test']},{r['map']},n={r['n']},{r['grid_steps']},"
              f"{r['waste']:.3f},build_us={r['us_per_call']:.0f}")
    print("# ==== compiled execution (autotuned kinds) ====")
    rcomp = _compiled_rows()
    for r in rcomp:
        print(f"{r['test']},{r['map']},{r['grid_steps']},"
              f"{r['us_per_call']:.0f},src={r.get('autotune_source', '-')}")
    print("# ==== engine parity (differential: engine vs legacy/ref) ====")
    rp = _engine_parity_rows()
    for r in rp:
        print(f"{r['test']},{r['body']},{r['map']},m={r['m']},"
              f"err={r['max_abs_err']:.2e}")
    print("# ==== §7: sharded simplex execution (fold skew) ====")
    rs = _shard_rows()
    for r in rs:
        print(f"{r['test']},{r['map']},m={r['m']},n={r['n']},"
              f"k={r['shards']},skew={r['skew']:.4f},"
              f"slab={r.get('slab_skew', float('nan')):.3f},"
              f"bit_exact={r.get('bit_exact', '-')}")
    print("# ==== Fig.12/15: energy (modeled) ====")
    re = bench_energy.main()
    print("# ==== §6: general-m (r,beta) ====")
    rg = bench_general_m.main()
    print("# ==== beyond-paper: folded causal attention ====")
    ra = bench_attention.main()
    print("# ==== §8: serving attention (tokens/s per executor) ====")
    ratt = bench_attention.serving_rows()
    for r in ratt:
        print(f"{r['test']},{r['map']},n={r['n']},steps={r['grid_steps']},"
              f"tok_s_prefill={r['tok_s_prefill']:.0f},"
              f"tok_s_decode={r.get('tok_s_decode', float('nan')):.0f},"
              f"step_ratio={r['step_ratio']:.2f}")

    path = write_maps_artifact(
        r2 + r3 + rm + rc + rcomp + rp + rs + ratt, path=out
    )
    validate_artifact(path)
    print(f"# wrote + validated {path}")

    print("# ==== summary: name,us_per_call,derived ====")
    for r in r2:
        print(f"fig10/{r['test']}/{r['map']},{r['us_per_call']:.0f},"
              f"space_speedup={r['space_speedup_vs_bb']:.3f}")
    for r in r3:
        us = r["us_per_call"]
        print(f"fig13/{r['test']}/{r['map']},"
              f"{us if not math.isnan(us) else 0:.0f},"
              f"space_speedup={r['space_speedup_vs_bb']:.3f}")
    for r in rm:
        print(f"md/{r['test']}/{r['map']},{r['us_per_call']:.0f},"
              f"space_speedup={r['space_speedup_vs_bb']:.3f}")
    for r in rc:
        print(f"sched/{r['test']}/{r['map']}/n={r['n']},"
              f"{r['us_per_call']:.0f},waste={r['waste']:.3f}")
    for r in rcomp:
        print(f"compiled/{r['test']}/{r['map']},{r['us_per_call']:.0f},"
              f"autotune={r.get('autotune_source', '-')}")
    for r in rs:
        print(f"shard/m={r['m']}/n={r['n']}/k={r['shards']},0,"
              f"skew={r['skew']:.4f}")
    for r in re:
        print(f"fig12/{r['test']}/{r['map']},0,"
              f"eps_per_w_vs_bb={r['eps_per_w_vs_bb']:.2f}")
    for r in rg:
        print(f"sec6/m={r['m']},0,speedup={r['speedup_vs_bb']:.1f}")
    for r in ra:
        print(f"attn/{r['shape']},{r['folded_us']:.0f},"
              f"wall_speedup={r['wall_speedup']:.2f}")
    for r in ratt:
        print(f"serve-attn/{r['map']}/s={r['seq']},{r['us_per_call']:.0f},"
              f"tok_s_prefill={r['tok_s_prefill']:.0f}")
    print(f"# total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
