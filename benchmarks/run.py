"""Benchmark harness aggregator — one section per paper table/figure.

  Fig. 10  -> bench_maps_2simplex   (2-simplex: MAP/ACCUM/EDM/CA2D)
  Fig. 13  -> bench_maps_3simplex   (3-simplex: MAP3D/ACCUM3D/CA3D)
  Fig12/15 -> bench_energy          (EPS/W, modeled — DESIGN.md §2)
  §6/Thm6.2-> bench_general_m       ((r, beta) optimization table)
  beyond   -> bench_attention       (folded-simplex causal attention)

Prints ``name,us_per_call,derived`` CSV lines per benchmark plus the
full per-table CSVs.  Roofline tables come from the dry-run artifacts
(see EXPERIMENTS.md §Roofline), not from this harness.
"""

from __future__ import annotations

import math
import time


def main() -> None:
    from . import (
        bench_attention,
        bench_energy,
        bench_general_m,
        bench_maps_2simplex,
        bench_maps_3simplex,
    )

    t0 = time.time()
    print("# ==== Fig.10: 2-simplex maps ====")
    r2 = bench_maps_2simplex.main()
    print("# ==== Fig.13: 3-simplex maps ====")
    r3 = bench_maps_3simplex.main()
    print("# ==== Fig.12/15: energy (modeled) ====")
    re = bench_energy.main()
    print("# ==== §6: general-m (r,beta) ====")
    rg = bench_general_m.main()
    print("# ==== beyond-paper: folded causal attention ====")
    ra = bench_attention.main()

    print("# ==== summary: name,us_per_call,derived ====")
    for r in r2:
        print(f"fig10/{r['test']}/{r['map']},{r['us_per_call']:.0f},"
              f"space_speedup={r['space_speedup_vs_bb']:.3f}")
    for r in r3:
        us = r["us_per_call"]
        print(f"fig13/{r['test']}/{r['map']},"
              f"{us if not math.isnan(us) else 0:.0f},"
              f"space_speedup={r['space_speedup_vs_bb']:.3f}")
    for r in re:
        print(f"fig12/{r['test']}/{r['map']},0,"
              f"eps_per_w_vs_bb={r['eps_per_w_vs_bb']:.2f}")
    for r in rg:
        print(f"sec6/m={r['m']},0,speedup={r['speedup_vs_bb']:.1f}")
    for r in ra:
        print(f"attn/{r['shape']},{r['folded_us']:.0f},"
              f"wall_speedup={r['wall_speedup']:.2f}")
    print(f"# total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
