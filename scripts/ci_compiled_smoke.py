"""CI smoke for the compiled execution path (DESIGN.md §5).

Runs one compiled ``accum_md`` configuration end to end — autotuner
decision, fused-XLA executor, numpy parity — and checks the compiled
index_map against the host-built step list for one schedule of every
registered kind.  Exits non-zero on any parity failure; the workflow
then runs ``benchmarks/run.py --quick`` for the schema gate.

Usage:  PYTHONPATH=src python scripts/ci_compiled_smoke.py
"""

import os
import sys

import numpy as np


def main() -> int:
    os.environ.setdefault("REPRO_AUTOTUNE_DISABLE", "1")  # hermetic
    import jax.numpy as jnp

    from repro.autotune import choose_kind
    from repro.core.schedule import SimplexSchedule, registered_kinds
    from repro.kernels.compiled import (
        accum_md_compiled,
        schedule_coords_compiled,
    )

    # -- one compiled accum_md config, autotuned kind -----------------
    m, n, rho = 3, 32, 4
    decision = choose_kind(m, n // rho)
    x = (np.arange(n**m, dtype=np.int32).reshape((n,) * m)) % 41
    got = np.asarray(
        accum_md_compiled(jnp.asarray(x), rho=rho, kind=decision.kind)
    )
    ii = np.arange(n)
    inside = (
        ii[:, None, None] + ii[None, :, None] + ii[None, None, :]
    ) < n
    want = x + inside.astype(np.int32)
    if not np.array_equal(got, want):
        print(f"FAIL: compiled accum_md parity (kind={decision.kind})")
        return 1
    print(f"ok: compiled accum_md m={m} n={n} kind={decision.kind} "
          f"(source={decision.source})")

    # -- compiled index_map == host step list, every kind -------------
    probe = {"hmap": (3, 8), "octant": (3, 8), "rb": (2, 8),
             "bb": (3, 6), "table": (3, 6), "composite": (3, 6)}
    for kind, (pm, pn) in probe.items():
        if kind not in registered_kinds(pm):
            continue
        coords = schedule_coords_compiled(pm, pn, kind)
        table = np.asarray(SimplexSchedule(pm, pn, kind).table())
        if not np.array_equal(coords.astype(np.int64),
                              table.astype(np.int64)):
            print(f"FAIL: index_map parity kind={kind} (m={pm}, n={pn})")
            return 1
        print(f"ok: index_map parity kind={kind} ({len(table)} steps)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
