"""Recompute rec['roofline'] for all dry-run JSONs (terms are derived
from stored fields — no recompilation needed)."""
import json, glob, os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.roofline.analysis import roofline_terms

for f in glob.glob("experiments/dryrun/*/*.json"):
    r = json.load(open(f))
    if r.get("status") != "ok":
        continue
    r["model_axis"] = 16
    r["roofline"] = roofline_terms(r)
    json.dump(r, open(f, "w"), indent=1)
print("rederived", len(glob.glob("experiments/dryrun/*/*.json")))
