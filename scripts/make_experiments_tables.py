"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSON artifacts.  Usage:
  PYTHONPATH=src python scripts/make_experiments_tables.py > /tmp/tables.md
"""

from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.roofline.analysis import load_cells  # noqa: E402

ARCH_ORDER = [
    "seamless-m4t-large-v2", "stablelm-12b", "yi-6b", "granite-8b",
    "internlm2-20b", "deepseek-v3-671b", "qwen2-moe-a2.7b", "qwen2-vl-72b",
    "jamba-v0.1-52b", "xlstm-350m",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def fmt_b(x):
    for unit, s in [(1e12, "TB"), (1e9, "GB"), (1e6, "MB"), (1e3, "KB")]:
        if x >= unit:
            return f"{x/unit:.2f}{s}"
    return f"{x:.0f}B"


def bottleneck_note(rec):
    rf = rec["roofline"]
    dom = rf["dominant"]
    arch = rec["arch"]
    if dom == "collective":
        kinds = rec["collectives"]["per_kind"]
        big = max(kinds, key=lambda k: kinds[k]["wire_bytes"]) if kinds else "?"
        return (f"{big} traffic dominates — reduce cross-shard reshards "
                f"(sharding/overlap change)")
    if dom == "memory":
        if rf["useful_ratio"] < 0.3:
            return "HBM-bound with low useful compute — fuse/remat-policy + layout"
        return "HBM-bound — raise arithmetic intensity (larger micro-tiles)"
    return "compute-bound — already near the MXU roof; tighten schedule waste"


def main():
    outdir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    base = {}
    for mesh in ("pod16x16", "pod2x16x16"):
        for rec in load_cells(outdir, mesh):
            if rec.get("overrides"):
                continue
            base[(mesh, rec["arch"], rec["shape"])] = rec

    print("### Dry-run matrix (status; compile proves the sharding is coherent)\n")
    print("| arch | shape | 16x16 (256 chips) | 2x16x16 (512 chips) |")
    print("|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r1 = base.get(("pod16x16", a, s))
            r2 = base.get(("pod2x16x16", a, s))

            def cell(r):
                if r is None:
                    return "(pending)"
                if r["status"] == "skip":
                    return "SKIP (full-attn @500k)"
                if r["status"] == "error":
                    return "ERROR"
                mem = r["memory"]
                per_dev = mem["argument_size"] + mem["temp_size"]
                return (f"OK — args+temp {fmt_b(per_dev)}/dev, "
                        f"compile {r['seconds_compile']:.0f}s")

            print(f"| {a} | {s} | {cell(r1)} | {cell(r2)} |")

    print("\n### Roofline (single-pod 16x16, v5e: 197 TF/s bf16, 819 GB/s HBM,"
          " 50 GB/s/link)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "MODEL_FLOPS | useful | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            rec = base.get(("pod16x16", a, s))
            if rec is None or rec["status"] != "ok":
                continue
            rf = rec["roofline"]
            print(
                f"| {a} | {s} | {fmt_s(rf['compute_s'])} | "
                f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
                f"**{rf['dominant']}** | {rf['model_flops']:.3g} | "
                f"{rf['useful_ratio']:.2f} | {bottleneck_note(rec)} |"
            )

    tuned = {}
    for rec in load_cells("experiments/dryrun_tuned", "pod16x16"):
        tuned[(rec["arch"], rec["shape"])] = rec
    if tuned:
        print("\n### Baseline vs optimized-v1 (single-pod; §Perf defaults:"
              " shard_map folded attention + per-family tp/mb tuning)\n")
        print("| arch | shape | frac baseline | frac optimized | Δ | "
              "dominant (opt) |")
        print("|---|---|---|---|---|---|")
        for a in ARCH_ORDER:
            for s in SHAPE_ORDER:
                r0 = base.get(("pod16x16", a, s))
                r1 = tuned.get((a, s))
                if not r0 or not r1 or r0["status"] != "ok" \
                        or r1["status"] != "ok":
                    continue
                f0 = r0["roofline"]["roofline_fraction"]
                f1 = r1["roofline"]["roofline_fraction"]
                print(f"| {a} | {s} | {f0:.3g} | {f1:.3g} | "
                      f"{f1/f0:.2f}x | {r1['roofline']['dominant']} |")

    print("\n### Collective census (single-pod, wire bytes/chip/step)\n")
    print("| arch | shape | all-reduce | all-gather | reduce-scatter | "
          "all-to-all | collective-permute | total |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            rec = base.get(("pod16x16", a, s))
            if rec is None or rec["status"] != "ok":
                continue
            per = rec["collectives"]["per_kind"]

            def w(k):
                return fmt_b(per[k]["wire_bytes"]) if k in per else "-"

            print(f"| {a} | {s} | {w('all-reduce')} | {w('all-gather')} | "
                  f"{w('reduce-scatter')} | {w('all-to-all')} | "
                  f"{w('collective-permute')} | "
                  f"{fmt_b(rec['collectives']['wire_bytes_per_chip'])} |")


if __name__ == "__main__":
    main()
