#!/usr/bin/env python
"""Static verifier for Pallas kernels and simplex schedules.

Thin CLI wrapper over ``repro.analysis`` (DESIGN.md §9).  Usage::

    PYTHONPATH=src python scripts/simplexlint.py            # text report
    PYTHONPATH=src python scripts/simplexlint.py --json     # CI report
    PYTHONPATH=src python scripts/simplexlint.py --fix      # mechanical fixes
    PYTHONPATH=src python scripts/simplexlint.py --list     # pass inventory

Exits 0 when every registered pass is clean, 1 on any finding.
"""

import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.analysis.cli import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(["--root", str(REPO)] + sys.argv[1:]))
