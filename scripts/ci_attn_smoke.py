"""CI smoke for the attention serving hot path (DESIGN.md §8).

Runs ``serve_lm``'s smoke-scale workload (reduced yi-6b, batch 4,
prompt 64) through ``attn_apply``'s dispatch three ways and asserts:

* the autotune decision at the serve shape picks the folded flash
  kernel (the tentpole default);
* folded-vs-chunked **bit**-parity at the decision's tile, and flash
  bb-vs-folded bit-parity, through the real model prefill;
* decode through the KV-cache strip path still generates (tokens/s
  printed), i.e. the serve loop runs end to end with flash prefill.

Exits non-zero on any mismatch; the workflow then runs
``benchmarks/run.py --quick``, which emits + validates the quick ATTN
tokens/s rows.

Usage:  PYTHONPATH=src python scripts/ci_attn_smoke.py
"""

import os
import sys
import time

import numpy as np


def main() -> int:
    os.environ.setdefault("REPRO_AUTOTUNE_DISABLE", "1")  # hermetic
    import jax
    import jax.numpy as jnp

    from repro.autotune import choose_attn_impl
    from repro.configs.ALL import REDUCED
    from repro.models.model import Model

    cfg0 = REDUCED["yi-6b"]().replace(
        act_dtype="float32", param_dtype="float32", remat="none"
    )
    b, s, gen = 4, 64, 8

    dec = choose_attn_impl(s, cfg0.n_heads, cfg0.hd)
    print(f"decision: impl={dec.impl} kind={dec.kind} "
          f"block={dec.block_q} source={dec.source}")
    if (dec.impl, dec.kind) != ("flash", "folded"):
        print("FAIL: serve-shape decision is not folded flash")
        return 1

    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (b, s), 0, cfg0.vocab)
    logits = {}
    caches = None
    for impl in ("flash-folded", "flash-bb", "chunked"):
        # chunk = the decision's tile so the XLA walk shares the flash
        # kernel's tiling/reduction order -> bit-comparable outputs
        cfg = cfg0.replace(attention_impl=impl,
                           attention_chunk=dec.block_q)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        lg, cc = jax.jit(model.prefill)(params, {"tokens": tokens})
        logits[impl] = np.asarray(jax.block_until_ready(lg))
        if impl == "flash-folded":
            caches, fold_model, fold_params = cc, model, params

    for other in ("flash-bb", "chunked"):
        if not np.array_equal(logits["flash-folded"], logits[other]):
            err = np.abs(logits["flash-folded"] - logits[other]).max()
            print(f"FAIL: folded-vs-{other} prefill logits differ "
                  f"(max abs {err})")
            return 1
    print(f"prefill bit-parity OK across executors "
          f"(batch {b} x {s} tokens, tile {dec.block_q})")

    decode = jax.jit(fold_model.decode)
    tok = jnp.argmax(logits["flash-folded"][:, -1], -1)[:, None]
    tok = tok.astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(gen):
        sb = {"tokens": tok, "pos": jnp.full((b,), s + i, jnp.int32)}
        lg, _ = decode(fold_params, caches, sb)
        tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"decode OK: {gen} x {b} tokens ({gen * b / dt:.0f} tok/s)")
    print("ATTN smoke PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
