"""Distribution: sharding rules + small-mesh lowering integration tests.

Runs on 8 forced host devices (set in conftest for THIS module only via
subprocess-free trick: these tests require the session to have >= 4
devices; they skip when the session was initialized single-device —
the dry-run entry point and CI script run them under XLA_FLAGS).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.ALL import ARCH_IDS, REDUCED
from repro.configs.base import ShapeCfg

needs_devices = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >=4 devices (run under "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


def _mesh():
    from repro.launch.mesh import make_mesh

    return make_mesh((2, 2), ("data", "model"))


@needs_devices
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_lowering_compiles(arch):
    from repro.launch.steps import build

    cfg = REDUCED[arch]()
    b = build(cfg, _mesh(), ShapeCfg("t", 64, 8, "train", microbatches=2))
    co = b.lower_train().compile()
    assert co.cost_analysis() is not None


@needs_devices
@pytest.mark.parametrize("arch", ["yi-6b", "deepseek-v3-671b", "jamba-v0.1-52b",
                                  "xlstm-350m"])
def test_serve_lowering_compiles(arch):
    from repro.launch.steps import build

    cfg = REDUCED[arch]()
    b = build(cfg, _mesh(), ShapeCfg("d", 64, 8, "decode"))
    b.lower_serve().compile()


@needs_devices
def test_sharded_train_step_runs_and_matches_single_device():
    """Numerical equivalence: the distributed train step on a 2x2 mesh
    computes the same loss as the single-device path."""
    from repro.launch.steps import build
    from repro.models.model import Model

    cfg = REDUCED["yi-6b"]().replace(param_dtype="float32", act_dtype="float32")
    shape = ShapeCfg("t", 32, 4, "train", microbatches=1)
    mesh = _mesh()
    bundle = build(cfg, mesh, shape)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = bundle.opt.init(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0, cfg.vocab)
    batch = {"tokens": tokens}
    # reference BEFORE the step: jit_train donates params/opt_state
    ref_loss = float(model.loss(params, batch)[0])
    step_fn = bundle.jit_train()
    new_p, new_o, step, metrics = step_fn(
        params, opt_state, jnp.zeros((), jnp.int32), batch
    )
    dist_loss = float(metrics["loss"])
    assert np.isfinite(dist_loss)
    np.testing.assert_allclose(dist_loss, ref_loss, rtol=2e-4)


@needs_devices
def test_param_specs_divisibility():
    """Every spec produced is legal for its leaf (the seamless vocab
    256206 case must fall back to replication, not crash)."""
    from repro.distributed.sharding import param_specs
    from repro.models.model import Model

    mesh = _mesh()
    for arch in ARCH_IDS:
        cfg = REDUCED[arch]()
        sds = jax.eval_shape(lambda: Model(cfg).init(jax.random.PRNGKey(0)))
        specs = param_specs(sds, mesh)

        def check(leaf, spec):
            for i, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = (ax,) if isinstance(ax, str) else ax
                size = int(np.prod([mesh.shape[a] for a in axes]))
                assert leaf.shape[i] % size == 0, (arch, leaf.shape, spec)

        jax.tree_util.tree_map(
            check, sds, specs, is_leaf=lambda x: isinstance(x, P)
        )


@needs_devices
def test_moe_sharded_matches_local():
    from repro.models.moe import moe_apply, moe_init

    cfg = REDUCED["qwen2-moe-a2.7b"]().replace(
        param_dtype="float32", act_dtype="float32"
    )
    mesh = _mesh()
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    out_local, aux_local = moe_apply(p, cfg, x)
    out_dist, aux_dist = jax.jit(
        lambda p, x: moe_apply(p, cfg, x, mesh)
    )(p, x)
    # capacity grouping differs (global vs per-dp-shard groups) — the
    # routing itself must agree on non-dropped tokens; compare loosely.
    assert out_dist.shape == out_local.shape
    assert np.isfinite(np.asarray(out_dist)).all()
    corr = np.corrcoef(
        np.asarray(out_dist).ravel(), np.asarray(out_local).ravel()
    )[0, 1]
    assert corr > 0.98


def test_cache_specs_generic_rule():
    from repro.distributed.sharding import cache_specs
    from repro.models.model import Model

    if jax.device_count() < 4:
        pytest.skip("needs mesh")
    mesh = _mesh()
    cfg = REDUCED["jamba-v0.1-52b"]()
    m = Model(cfg)
    cache = jax.eval_shape(lambda: m.init_cache(1, 64, jnp.bfloat16))
    specs = cache_specs(cache, mesh)  # batch=1: nothing sharded over dp

    def check(leaf, spec):
        assert spec[0] is None or leaf.shape[0] % 2 == 0

    jax.tree_util.tree_map(
        check, cache, specs, is_leaf=lambda x: isinstance(x, P)
    )
