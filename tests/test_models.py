"""Model stack: per-arch smoke (reduced config, one forward/train step on
CPU, output shapes + no NaNs), mixer-level consistency, attention
schedule equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.ALL import ARCH_IDS, REDUCED
from repro.configs.base import get_config
from repro.kernels import ref as R
from repro.models.attention import chunked_causal_attention
from repro.models.model import Model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32, train=True):
    extra = 1 if train else 0
    batch = {"tokens": jax.random.randint(KEY, (b, s + extra), 0, cfg.vocab)}
    if cfg.n_patches:
        batch["tokens"] = batch["tokens"][:, : s + extra - cfg.n_patches]
        batch["patches"] = jax.random.normal(
            KEY, (b, cfg.n_patches, cfg.d_model), jnp.float32
        )
    if cfg.encoder_layers:
        batch["src_embeds"] = jax.random.normal(
            KEY, (b, s, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = REDUCED[arch]().replace(param_dtype="float32", act_dtype="float32")
    m = Model(cfg)
    params = m.init(KEY)
    batch = _batch(cfg)
    loss, metrics = m.loss(params, batch)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(np.isfinite(np.asarray(x)).all() for x in leaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = REDUCED[arch]().replace(param_dtype="float32", act_dtype="float32")
    m = Model(cfg)
    params = m.init(KEY)
    batch = _batch(cfg, train=False)
    logits, caches = m.prefill(params, batch)
    assert logits.shape[-1] == cfg.vocab
    assert np.isfinite(np.asarray(logits)).all()
    dec = {
        "tokens": jax.random.randint(KEY, (2, 1), 0, cfg.vocab),
        "pos": jnp.full((2,), 32, jnp.int32),
    }
    logits2, _ = m.decode(params, caches, dec)
    assert logits2.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("arch", ["yi-6b", "stablelm-12b", "granite-8b"])
def test_decode_matches_full_forward(arch):
    """Decode at position t must equal the train forward's position t."""
    cfg = REDUCED[arch]().replace(param_dtype="float32", act_dtype="float32")
    m = Model(cfg)
    params = m.init(KEY)
    b, s = 2, 33
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    # full forward logits at position s-1 predicting next
    full_logits = _all_logits(m, params, tokens)
    logits_pref, caches = m.prefill(params, {"tokens": tokens[:, : s - 1]})
    np.testing.assert_allclose(
        np.asarray(logits_pref[:, 0]),
        np.asarray(full_logits[:, s - 2]),
        rtol=2e-3, atol=2e-4,
    )
    dec = {"tokens": tokens[:, s - 1 :], "pos": jnp.full((b,), s - 1, jnp.int32)}
    logits_dec, _ = m.decode(params, caches, dec)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]),
        np.asarray(full_logits[:, s - 1]),
        rtol=2e-3, atol=2e-4,
    )


def _all_logits(m, params, tokens):
    x, positions, pos3 = m._embed_inputs(params, {"tokens": tokens})
    h, _, _ = m._backbone(params, x, positions, mode="train")
    return m._logits(params, h)


def test_folded_equals_bb_schedule_end_to_end():
    """The paper's simplex schedule must be numerically equivalent to the
    bounding-box baseline — it only removes wasted tiles."""
    cfg = REDUCED["yi-6b"]().replace(param_dtype="float32", act_dtype="float32")
    m = Model(cfg)
    params = m.init(KEY)
    batch = _batch(cfg, s=64)
    l1, _ = m.loss(params, batch)
    cfg2 = cfg.replace(attention_schedule="bb")
    l2, _ = Model(cfg2).loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


@pytest.mark.parametrize("s,chunk", [(128, 32), (256, 64), (96, 32)])
def test_chunked_attention_schedules_match(s, chunk):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 4, s, 32))
    k = jax.random.normal(ks[1], (2, 2, s, 32))
    v = jax.random.normal(ks[2], (2, 2, s, 32))
    ref = R.causal_attention(q, k, v)
    for sched in ["folded", "bb"]:
        got = chunked_causal_attention(q, k, v, chunk=chunk, schedule=sched)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref), rtol=2e-4, atol=2e-5
        )


def test_full_configs_match_assignment():
    """The exact numbers from the assignment table."""
    spec = {
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
    }
    for name, (L, d, h, kv, ff, v) in spec.items():
        c = get_config(name)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab) == (L, d, h, kv, ff, v), name
    ds = get_config("deepseek-v3-671b")
    assert (ds.n_layers, ds.d_model, ds.n_heads, ds.vocab) == (
        61, 7168, 128, 129280)
    assert ds.moe.n_experts == 256 and ds.moe.top_k == 8
    assert ds.moe.n_shared == 1 and ds.moe.expert_ff == 2048
    assert ds.mtp and ds.attention == "mla"
    jb = get_config("jamba-v0.1-52b")
    assert jb.moe.n_experts == 16 and jb.moe.top_k == 2
    assert sum(s.mixer == "attn" for s in jb.period) == 1  # 1:7 interleave
    assert sum(s.ffn == "moe" for s in jb.period) == 4  # MoE every 2
    qm = get_config("qwen2-moe-a2.7b")
    assert qm.moe.n_experts == 60 and qm.moe.top_k == 4 and qm.moe.n_shared == 4
    assert get_config("seamless-m4t-large-v2").encoder_layers == 24
    assert get_config("qwen2-vl-72b").mrope_sections == (16, 24, 24)
    assert get_config("xlstm-350m").sub_quadratic
    assert get_config("jamba-v0.1-52b").sub_quadratic


def test_mtp_loss_present_for_deepseek():
    cfg = REDUCED["deepseek-v3-671b"]().replace(
        param_dtype="float32", act_dtype="float32"
    )
    m = Model(cfg)
    params = m.init(KEY)
    assert "mtp" in params
    loss, metrics = m.loss(params, _batch(cfg))
    assert float(loss) > float(metrics["ce"])  # mtp adds a positive term
