"""Pytest bridge for the simplexlint registry (DESIGN.md §9).

Three layers:
  * the tier-1 bridge — the full registry runs clean on the real tree
    (same invocation as ``scripts/simplexlint.py`` / CI);
  * AST fixture tests — each policy pass flags exactly its seeded
    violation under ``tests/fixtures_lint/bad`` and accepts the clean
    fixture module;
  * semantic violator tests — corrupted schedule views and
    mis-declared kernel bodies built in code, so the write-race,
    bijectivity, and halo-conformance checkers each catch a seeded
    violation without touching the real registry.
"""

import json
import pathlib
import shutil

import numpy as np
import pytest

from repro.analysis import (
    findings_to_json,
    get_pass,
    registered_passes,
    run_passes,
)
from repro.analysis.halo_passes import HALO_MN, check_body_halo
from repro.analysis.schedule_passes import (
    DEFAULT_MN,
    check_schedule_bijectivity,
    check_schedule_race,
    verified_schedules,
)
from repro.core.schedule import SimplexSchedule

REPO = pathlib.Path(__file__).resolve().parents[1]
BAD = REPO / "tests" / "fixtures_lint" / "bad"
CLEAN = REPO / "tests" / "fixtures_lint" / "clean"

AST_PASSES = ("design-xref", "hardcoded-interpret", "pallas-front-door",
              "shim-deprecation", "tile-alignment")


# --------------------------------------------------------------------------
# tier-1 bridge: the registry is clean on the merged tree
# --------------------------------------------------------------------------

def test_registry_clean_on_repo():
    findings = run_passes(REPO)
    assert not findings, "\n".join(f.format() for f in findings)


def test_registry_contents():
    names = registered_passes()
    for expected in AST_PASSES + (
        "schedule-bijectivity", "write-race", "halo-conformance",
    ):
        assert expected in names
    assert get_pass("hardcoded-interpret").fix is not None
    with pytest.raises(ValueError):
        get_pass("no-such-pass")


# --------------------------------------------------------------------------
# AST passes against the seeded fixtures
# --------------------------------------------------------------------------

@pytest.mark.parametrize("pass_name,fixture,needle", [
    ("pallas-front-door", "rogue_pallas.py", "front"),
    ("hardcoded-interpret", "hard_interp.py", "interpret=True"),
    ("shim-deprecation", "shim_silent.py", "DeprecationWarning"),
    ("design-xref", "stale_xref.py", "stale cross-reference"),
    ("tile-alignment", "bad_tile.py", "sublane"),
])
def test_ast_pass_flags_exactly_its_fixture(pass_name, fixture, needle):
    findings = run_passes(REPO, src_root=BAD, passes=[pass_name])
    assert findings, f"{pass_name} missed its seeded violation"
    assert all(f.pass_name == pass_name for f in findings)
    # exactly the intended fixture file is flagged, nothing else
    assert {pathlib.Path(f.path).name for f in findings} == {fixture}
    assert any(needle in f.message for f in findings)


def test_shim_pass_flags_all_three_contract_breaks():
    msgs = [
        f.message
        for f in run_passes(REPO, src_root=BAD, passes=["shim-deprecation"])
    ]
    assert any("silent_shim" in m for m in msgs)  # delegates, no warning
    assert any("warning_reimplementor" in m for m in msgs)  # no delegation
    assert any("SilentShimClass" in m for m in msgs)  # class, no warning


def test_clean_fixture_passes_every_ast_pass():
    findings = run_passes(REPO, src_root=CLEAN, passes=list(AST_PASSES))
    assert not findings, "\n".join(f.format() for f in findings)


def test_fixer_rewrites_hardcoded_interpret(tmp_path):
    src = tmp_path / "src"
    src.mkdir()
    shutil.copy(BAD / "hard_interp.py", src / "hard_interp.py")
    before = run_passes(tmp_path, src_root=src,
                        passes=["hardcoded-interpret"])
    assert before and before[0].fixable
    after = run_passes(tmp_path, src_root=src,
                       passes=["hardcoded-interpret"], fix=True)
    assert not after
    fixed = (src / "hard_interp.py").read_text()
    assert 'engine.accum(x, rho=2, kind="bb", interpret=None)' in fixed


def test_json_report_schema():
    findings = run_passes(REPO, src_root=BAD, passes=["tile-alignment"])
    doc = json.loads(findings_to_json(findings, ["tile-alignment"]))
    assert doc["version"] == 1
    assert doc["passes"] == ["tile-alignment"]
    assert doc["counts"] == {"tile-alignment": len(findings)}
    assert len(doc["findings"]) == len(findings) > 0
    assert set(doc["findings"][0]) == {
        "pass", "path", "line", "message", "fixable",
    }


def test_cli_exit_codes(capsys):
    from repro.analysis.cli import main

    assert main(["--root", str(REPO)]) == 0
    capsys.readouterr()
    assert main(["--root", str(REPO), "--list"]) == 0
    listed = capsys.readouterr().out
    for name in registered_passes():
        assert name in listed


# --------------------------------------------------------------------------
# semantic violators built in code
# --------------------------------------------------------------------------

class _Corrupted:
    """Schedule view whose evaluated walk is mutated post hoc, so each
    semantic checker can be fed exactly one seeded violation."""

    def __init__(self, base, mutate):
        self._base = base
        self._mutate = mutate
        self.m, self.n = base.m, base.n
        self.kind = f"corrupted-{base.kind}"
        self.grid, self.steps = base.grid, base.steps
        self.prefetch = getattr(base, "prefetch", None)

    def map(self, *ws):
        out = self._base.map(*ws)
        coords = [np.asarray(c).astype(np.int64).copy() for c in out[:-1]]
        valid = np.asarray(out[-1]).astype(bool).copy()
        self._mutate(coords, valid)
        return tuple(coords) + (valid,)


def _first_valid_pair(base):
    from repro.analysis.schedule_passes import eval_schedule_map

    _, valid = eval_schedule_map(base)
    idx = np.nonzero(valid)[0]
    return int(idx[0]), int(idx[1])


def test_write_race_catches_duplicate_output_block():
    base = SimplexSchedule(2, 4, "bb")
    i, j = _first_valid_pair(base)

    def mutate(coords, valid):
        for c in coords:
            c[j] = c[i]

    findings = check_schedule_race(_Corrupted(base, mutate), 2, 4)
    assert findings
    assert all("write race" in f.message for f in findings)
    assert not check_schedule_race(base, 2, 4)


def test_bijectivity_catches_coverage_hole():
    base = SimplexSchedule(2, 4, "bb")
    i, _ = _first_valid_pair(base)

    def mutate(coords, valid):
        valid[i] = False

    findings = check_schedule_bijectivity(_Corrupted(base, mutate), 2, 4)
    assert any("never visited" in f.message for f in findings)
    assert not check_schedule_bijectivity(base, 2, 4)


def test_bijectivity_catches_out_of_bounds():
    base = SimplexSchedule(2, 4, "bb")
    i, _ = _first_valid_pair(base)

    def mutate(coords, valid):
        coords[0][i] = 99

    findings = check_schedule_bijectivity(_Corrupted(base, mutate), 2, 4)
    assert any("out-of-bounds" in f.message for f in findings)


def test_halo_pass_catches_undeclared_read():
    from repro.kernels.engine import CABody

    class UnderDeclared(CABody):
        name = "lint-test-under-declared"

        def stencil(self, m):
            return ((0,) * m,)  # claims centre-only while halo=True

    findings = check_body_halo(UnderDeclared(), 2, 4, "bb")
    assert findings
    assert all("undeclared halo read" in f.message for f in findings)
    assert len(findings) == 3 ** 2 - 1  # every non-centre offset


def test_halo_pass_catches_stale_declaration():
    from repro.kernels.engine import AccumBody, halo_shifts

    class OverDeclared(AccumBody):
        name = "lint-test-over-declared"

        def stencil(self, m):
            return halo_shifts(m)  # claims a halo the engine never fetches

    findings = check_body_halo(OverDeclared(), 2, 4, "bb")
    assert findings
    assert all("stale stencil" in f.message for f in findings)


def test_halo_pass_clean_on_registered_bodies():
    from repro.analysis.halo_passes import _domain_bodies

    bodies = list(_domain_bodies())
    assert bodies
    for body in bodies:
        for m, nb, kind in HALO_MN:
            assert not check_body_halo(body, m, nb, kind)


def test_verified_matrix_covers_kinds_and_shards():
    from repro.core.schedule import registered_kinds, resolve_kind

    assert set(DEFAULT_MN) == {2, 3, 4}
    for m, ns in DEFAULT_MN.items():
        assert any(n & (n - 1) == 0 for n in ns)  # a pow2 side
        assert any(n & (n - 1) != 0 for n in ns)  # a non-pow2 side
        for n in ns:
            labels = [label for label, _ in verified_schedules(m, n)]
            assert any(lbl.startswith("shard(k=") for lbl in labels)
            resolved = {resolve_kind(m, n, k) for k in registered_kinds(m)}
            covered = {
                lbl.split("->")[-1] for lbl in labels
                if not lbl.startswith(("shard(", "composite-pieces"))
            }
            assert covered == resolved
