"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels import simplex_kernels as K
from repro.kernels.flash_attention import flash_attention, flash_grid_steps
from repro.kernels.hmap_mxu import hmap2_coords_mxu


@pytest.mark.parametrize("nb", [4, 16, 32])
@pytest.mark.parametrize("kind", ["hmap", "rb", "bb"])
def test_map2d_matches_schedule(nb, kind):
    got = np.asarray(K.map2d(nb, kind))
    want = R.map_table_2d(nb, kind)
    assert np.array_equal(got, want)


@pytest.mark.parametrize("n,rho", [(32, 4), (64, 8), (64, 16)])
@pytest.mark.parametrize("kind", ["hmap", "rb", "bb"])
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_accum2d(n, rho, kind, dtype):
    key = jax.random.PRNGKey(n + rho)
    x = jax.random.randint(key, (n, n), 0, 100).astype(dtype)
    got = K.accum2d(x, rho=rho, kind=kind)
    want = R.accum2d(x)
    m = np.asarray(R.tril_mask(n))
    np.testing.assert_allclose(np.asarray(got)[m], np.asarray(want)[m])
    # out-of-domain untouched (in-place semantics)
    np.testing.assert_allclose(np.asarray(got)[~m], np.asarray(x)[~m])


@pytest.mark.parametrize("n,d,rho", [(32, 4, 4), (64, 8, 8), (64, 16, 8)])
@pytest.mark.parametrize("kind", ["hmap", "rb", "bb"])
def test_edm2d(n, d, rho, kind):
    p = jax.random.normal(jax.random.PRNGKey(d), (n, d), dtype=jnp.float32)
    got = K.edm2d(p, rho=rho, kind=kind)
    want = R.edm2d(p)
    m = np.asarray(R.tril_mask(n))
    np.testing.assert_allclose(
        np.asarray(got)[m], np.asarray(want)[m], rtol=1e-6, atol=1e-6
    )


@pytest.mark.parametrize("kind", ["hmap", "rb", "bb"])
def test_ca2d_multi_step(kind):
    n, rho = 48, 8
    key = jax.random.PRNGKey(7)
    s = (jax.random.uniform(key, (n, n)) < 0.4).astype(jnp.int32)
    s = s * R.tril_mask(n, jnp.int32)
    ks = rs = s
    for _ in range(4):
        ks = K.ca2d(ks, rho=rho, kind=kind)
        rs = R.ca2d_step(rs)
    m = np.asarray(R.tril_mask(n))
    assert np.array_equal(np.asarray(ks)[m], np.asarray(rs)[m])


@pytest.mark.parametrize("kind", ["table", "octant", "hmap", "bb"])
@pytest.mark.parametrize("n,rho", [(8, 2), (16, 4)])
def test_accum3d(kind, n, rho):
    x = jax.random.randint(jax.random.PRNGKey(1), (n, n, n), 0, 50).astype(
        jnp.int32
    )
    got = K.accum3d(x, rho=rho, kind=kind)
    want = R.accum3d(x)
    m = np.asarray(R.tetra_mask(n))
    assert np.array_equal(np.asarray(got)[m], np.asarray(want)[m])


@pytest.mark.parametrize("kind", ["table", "octant", "bb"])
def test_ca3d(kind):
    n, rho = 16, 4
    key = jax.random.PRNGKey(3)
    s = (jax.random.uniform(key, (n, n, n)) < 0.35).astype(jnp.int32)
    s = s * R.tetra_mask(n, jnp.int32)
    ks = rs = s
    for _ in range(2):
        ks = K.ca3d(ks, rho=rho, kind=kind)
        rs = R.ca3d_step(rs)
    m = np.asarray(R.tetra_mask(n))
    assert np.array_equal(np.asarray(ks)[m], np.asarray(rs)[m])


@pytest.mark.parametrize("kind", ["table", "hmap", "bb"])
@pytest.mark.parametrize("n,rho", [(4, 2), (8, 2)])
def test_accum_md_m4(kind, n, rho):
    """The general-m kernel at m=4, driven by the unified schedules."""
    x = jax.random.randint(jax.random.PRNGKey(4), (n,) * 4, 0, 50).astype(
        jnp.int32
    )
    got = np.asarray(K.accum_md(x, rho=rho, kind=kind))
    mask = np.indices((n,) * 4).sum(0) < n
    want = np.asarray(x) + mask
    assert np.array_equal(got[mask], want[mask])
    # out-of-domain untouched (in-place semantics)
    assert np.array_equal(got[~mask], np.asarray(x)[~mask])


@pytest.mark.parametrize("kind", ["table", "hmap", "bb"])
def test_accum_md_matches_accum3d(kind):
    """At m=3 the generic kernel reduces to the dedicated 3D one."""
    n, rho = 8, 2
    x = jax.random.randint(jax.random.PRNGKey(6), (n, n, n), 0, 50).astype(
        jnp.int32
    )
    got = K.accum_md(x, rho=rho, kind=kind)
    want = K.accum3d(x, rho=rho, kind=kind)
    m = np.asarray(R.tetra_mask(n))
    assert np.array_equal(np.asarray(got)[m], np.asarray(want)[m])


@pytest.mark.parametrize(
    "b,hq,hkv,s,d,bq",
    [
        (1, 2, 2, 64, 16, 16),
        (2, 4, 2, 128, 32, 32),
        (1, 8, 1, 64, 64, 16),
        (1, 2, 2, 32, 16, 32),  # single tile -> bb fallback
    ],
)
@pytest.mark.parametrize("kind", ["folded", "bb"])
def test_flash_attention(b, hq, hkv, s, d, bq, kind):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype=jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype=jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype=jnp.float32)
    got = flash_attention(q, k, v, kind=kind, block_q=bq, block_kv=bq)
    want = R.causal_attention(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5
    )


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (1, 4, 128, 64), dtype=jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 128, 64),
                          dtype=jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 128, 64),
                          dtype=jnp.bfloat16)
    got = flash_attention(q, k, v, kind="folded", block_q=32, block_kv=32)
    want = R.causal_attention(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    )
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want), rtol=3e-2, atol=3e-2
    )


def test_flash_grid_step_counts():
    # the paper's MAP ratio: folded ~ half of bb (asymptotically)
    assert flash_grid_steps(16, "bb") == 256
    assert flash_grid_steps(16, "folded") == 8 * 17  # tri(16) + 8
    assert flash_grid_steps(128, "bb") / flash_grid_steps(128, "folded") > 1.9


def test_hmap_mxu_matches_scalar_map():
    from repro.core.hmap import hmap2

    n = 64
    wy, wx = np.meshgrid(np.arange(1, n), np.arange(n // 2), indexing="ij")
    wxy = np.stack([wx.ravel(), wy.ravel()], 1).astype(np.int32)
    pad = (-len(wxy)) % 128
    wxy_p = np.concatenate([wxy, np.ones((pad, 2), np.int32)], 0)
    got = np.asarray(hmap2_coords_mxu(jnp.asarray(wxy_p), rho=8))[: len(wxy)]
    ex, ey = hmap2(wxy[:, 0].astype(np.int64), wxy[:, 1].astype(np.int64))
    want = np.stack([ex * 8, ey * 8], 1)
    assert np.array_equal(got, want)
