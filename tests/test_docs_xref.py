"""DESIGN.md cross-reference audit (ISSUE 8 satellite).

PR 3 renumbered §5 -> §6 and a stale "§8" pointer survived in
``kernels/ops.py`` until this PR; this test keeps every
"DESIGN.md §x[.y]" string in ``src/`` honest by checking the section
actually exists as a DESIGN.md header (``## §N`` / ``### §N.M``).
"""

import pathlib
import re

REPO = pathlib.Path(__file__).resolve().parents[1]

SECTION_RE = re.compile(r"^#{2,}\s+(§\d+(?:\.\d+)?)\b", re.MULTILINE)
XREF_RE = re.compile(r"DESIGN\.md\s+(§\d+(?:\.\d+)?)")


def design_sections():
    text = (REPO / "DESIGN.md").read_text()
    return set(SECTION_RE.findall(text))


def test_design_has_sections():
    secs = design_sections()
    assert "§1" in secs and "§2.2" in secs, secs


def test_all_src_design_xrefs_exist():
    secs = design_sections()
    bad = []
    for root in ("src", "benchmarks", "scripts"):
        for path in sorted((REPO / root).rglob("*.py")):
            for ref in XREF_RE.findall(path.read_text()):
                if ref not in secs:
                    bad.append((str(path.relative_to(REPO)), ref))
    assert not bad, (
        f"stale DESIGN.md cross-references (existing: {sorted(secs)}): {bad}"
    )


def test_design_s8_attention_hot_path():
    # ISSUE 9: §8 documents the serving dispatch contract the code
    # points at (simplex_attention, choose_attn_impl, the fold diagram,
    # the decode exclusion).
    assert "§8" in design_sections()
    text = (REPO / "DESIGN.md").read_text()
    s8 = text.split("## §8", 1)[1]
    for needle in ("simplex_attention", "choose_attn_impl", "self-pair",
                   "bh // (Hq/Hkv)", "decode"):
        assert needle in s8, f"DESIGN.md §8 lost its {needle!r} contract"


def test_design_s9_static_verification():
    # ISSUE 10: §9 documents the simplexlint pass registry — the pass
    # model, both families, and how to register a new pass.
    assert "§9" in design_sections()
    text = (REPO / "DESIGN.md").read_text()
    s9 = text.split("## §9", 1)[1]
    for needle in ("register_pass", "write-race", "halo",
                   "bijectivity", "simplexlint", "fixtures_lint"):
        assert needle in s9, f"DESIGN.md §9 lost its {needle!r} contract"


def test_readme_static_checks():
    text = (REPO / "README.md").read_text()
    assert "## Static checks" in text
    sec = text.split("## Static checks", 1)[1].split("\n## ", 1)[0]
    for needle in ("simplexlint", "--json", "--fix", "DESIGN.md §9",
                   "test_simplexlint.py"):
        assert needle in sec, f"README static-checks section lost {needle!r}"


def test_readme_serving_quickstart():
    text = (REPO / "README.md").read_text()
    assert "## Serving-benchmark quickstart" in text
    quick = text.split("## Serving-benchmark quickstart", 1)[1]
    for needle in ("serve_lm.py", "attention_impl", "DESIGN.md §8",
                   "test_flash_parity.py"):
        assert needle in quick, f"README serving quickstart lost {needle!r}"
