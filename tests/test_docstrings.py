"""Doctest gate for the documented core scheduling API.

The docstring satellite of ISSUE 2, extended by ISSUE 8 to the
sharded-execution surface (``distributed/``, ``checkpoint/``): every
public symbol of the gated modules carries a doctest-style example;
running them here keeps the examples truthful (the ruff D1xx gate in
pyproject.toml keeps the *coverage* from regressing, this test keeps
the *content* from rotting).
"""

import doctest

import repro.autotune.tuner
import repro.core.schedule
import repro.core.trapezoids


def test_schedule_doctests():
    result = doctest.testmod(repro.core.schedule, verbose=False)
    assert result.failed == 0 and result.attempted > 0


def test_trapezoids_doctests():
    result = doctest.testmod(repro.core.trapezoids, verbose=False)
    assert result.failed == 0 and result.attempted > 0


def test_autotune_doctests(monkeypatch):
    monkeypatch.delenv("REPRO_SPLIT_PIECES", raising=False)
    result = doctest.testmod(repro.autotune.tuner, verbose=False)
    assert result.failed == 0 and result.attempted > 0


def test_engine_doctests():
    import repro.kernels.engine

    result = doctest.testmod(repro.kernels.engine, verbose=False)
    assert result.failed == 0 and result.attempted > 0


def test_simplex_sharding_doctests():
    import repro.distributed.simplex_sharding

    result = doctest.testmod(
        repro.distributed.simplex_sharding, verbose=False
    )
    assert result.failed == 0 and result.attempted > 0


def test_checkpointing_doctests():
    import repro.checkpoint.checkpointing

    result = doctest.testmod(repro.checkpoint.checkpointing, verbose=False)
    assert result.failed == 0 and result.attempted > 0


def test_flash_attention_doctests():
    # ISSUE 9 brings the attention hot path into the gate (DESIGN.md §8)
    import repro.kernels.flash_attention

    result = doctest.testmod(repro.kernels.flash_attention, verbose=False)
    assert result.failed == 0 and result.attempted > 0


def test_analysis_doctests():
    # ISSUE 10: the simplexlint subsystem documents itself (DESIGN.md §9)
    import repro.analysis
    import repro.analysis.registry
    import repro.analysis.schedule_passes

    for mod in (repro.analysis, repro.analysis.registry,
                repro.analysis.schedule_passes):
        result = doctest.testmod(mod, verbose=False)
        assert result.failed == 0 and result.attempted > 0, mod.__name__


def test_ops_doctests():
    # ISSUE 10 brings the public jit'd wrappers into the gate
    import repro.kernels.ops

    result = doctest.testmod(repro.kernels.ops, verbose=False)
    assert result.failed == 0 and result.attempted > 0


def test_mla_doctests():
    # ISSUE 10 brings the MLA latent-cache contract into the gate
    import repro.models.mla

    result = doctest.testmod(repro.models.mla, verbose=False)
    assert result.failed == 0 and result.attempted > 0
