"""Composite (general-n) schedule invariants — DESIGN.md §4.2.

The composite kind must serve *every* n at every m through analytical
maps: exhaustive bijectivity over all non-pow2 n <= 24 at m in {2,3,4},
kernel-facing resolution (`resolve_kind` never falls back to the O(V)
table walk at m >= 3 anymore), bounded waste, O(pieces) host-side
construction, and kernels consuming the composite walk unchanged.
"""

import numpy as np
import pytest

from repro.core.general_m import alpha_extra_space
from repro.core.schedule import SimplexSchedule, resolve_kind
from repro.core.simplex import simplex_volume
from repro.core.trapezoids import (
    composite_grid_size,
    composite_map,
    decompose_simplex,
)

NON_POW2 = [n for n in range(3, 25) if n & (n - 1)]


def _in_domain(m, coords, n):
    if m == 2:  # (col, row) lower-triangle convention
        return (
            (coords[:, 0] >= 0)
            & (coords[:, 0] <= coords[:, 1])
            & (coords[:, 1] < n)
        )
    return (coords >= 0).all(axis=1) & (coords.sum(axis=1) < n)


@pytest.mark.parametrize("m", [2, 3, 4])
@pytest.mark.parametrize("n", NON_POW2)
def test_composite_bijective_all_non_pow2(m, n):
    """Exhaustive oracle: the composite walk covers Delta^m_n exactly once."""
    sched = SimplexSchedule(m, n, "composite")
    tab = sched.table()
    assert tab.shape == (sched.steps, m + 1)
    valid = tab[:, -1] == 1
    coords = tab[valid, :-1]
    assert _in_domain(m, coords, n).all()
    pts = set(map(tuple, coords.tolist()))
    assert len(pts) == len(coords) == sched.useful == simplex_volume(n, m)


@pytest.mark.parametrize("m", [3, 4])
@pytest.mark.parametrize("n", NON_POW2)
def test_composite_coords_in_range_even_when_invalid(m, n):
    """Every step's coordinates — invalid ones included — stay in [0, n).

    Kernels feed schedule coordinates straight into BlockSpec index
    maps (only axis 0 is re-routed to the trash tile), so a dead cell
    must never report an out-of-range block index; raw dead-cell shears
    would go negative without the origin pin in composite_map.
    """
    tab = SimplexSchedule(m, n, "composite").table()
    coords = tab[:, :-1]
    assert (coords >= 0).all() and (coords < n).all()


@pytest.mark.parametrize("m", [3, 4])
@pytest.mark.parametrize("n", NON_POW2)
def test_resolve_kind_composite_not_table(m, n):
    """ISSUE acceptance: non-pow2 n at m >= 3 resolves hmap -> composite."""
    assert resolve_kind(m, n, "hmap") == "composite"
    if m == 3:
        assert resolve_kind(m, n, "octant") == "composite"


@pytest.mark.parametrize("m", [2, 3, 4])
@pytest.mark.parametrize("n", NON_POW2)
def test_composite_steps_within_waste_bound(m, n):
    """Property: composite steps <= table steps * (1 + waste bound).

    The table walk is exact (steps == V); the composite may only pay the
    recursion's asymptotic extra space plus the same 25% finite-n
    allowance the pow2 hmap tests use.  m=2 composite is exactly zero
    waste (every factor has dim <= 2).
    """
    comp = SimplexSchedule(m, n, "composite")
    table_steps = simplex_volume(n, m)  # table kind is exact by construction
    bound = 0.0 if m == 2 else alpha_extra_space(m, 2, m)
    assert comp.steps <= table_steps * (1.0 + bound + 0.25)
    assert comp.waste() <= bound + 0.25
    if m == 2:
        assert comp.steps == table_steps  # zero waste, any n


@pytest.mark.parametrize("m", [2, 3, 4])
def test_composite_construction_is_o_pieces_not_o_v(m):
    """Host-side cost scales with the piece count, never with V.

    Piece count is polylog in n — bounded by C(bits + m, m) = O(log^m n)
    — so at n = 2^20 - 1 (V ~ 10^17 at m=3) construction and
    .steps/.waste() must still be instant and table-free.
    """
    import math

    n = (1 << 20) - 1
    pieces = decompose_simplex(m, n)
    assert len(pieces) <= math.comb(n.bit_length() + m, m)
    sched = SimplexSchedule(m, n, "composite")
    assert sched.steps == composite_grid_size(m, n) >= sched.useful
    assert sched.prefetch is None  # pure arithmetic map, no O(V) payload
    assert sched.waste() >= 0.0


@pytest.mark.parametrize("m,n", [(2, 6), (3, 6), (3, 12), (4, 6)])
def test_composite_map_dual_backend(m, n):
    """The jax-traced composite map is bit-equal to the numpy walk."""
    import jax.numpy as jnp

    sched = SimplexSchedule(m, n, "composite")
    want = sched.table()
    lin = jnp.arange(sched.steps, dtype=jnp.int32)
    out = sched.map(lin)
    got = np.stack(
        [np.asarray(c, dtype=np.int64) for c in out[:-1]]
        + [np.asarray(out[-1]).astype(np.int64)],
        axis=1,
    )
    assert np.array_equal(got, want.astype(np.int64))


def test_decompose_simplex_partitions_exactly():
    """Piece volumes sum to V and pieces have pow2 factor sides."""
    for m in (2, 3, 4, 5):
        for n in (3, 7, 11, 24):
            pieces = decompose_simplex(m, n)
            assert sum(p.data_cells for p in pieces) == simplex_volume(n, m)
            for piece in pieces:
                dims = sum(d for d, _, _ in piece.groups)
                assert dims == m
                for d, s, _ in piece.groups[:-1]:  # prefixes are pow2
                    assert d >= 1 and s >= 1 and (s & (s - 1)) == 0


def test_composite_pow2_collapses_to_single_hmap_piece():
    """At pow2 n the decomposition is the plain recursion (one piece)."""
    for m in (2, 3, 4):
        pieces = decompose_simplex(m, 16)
        assert len(pieces) == 1 and pieces[0].groups == ((m, 16, 0),)


@pytest.mark.parametrize("kind", ["hmap", "composite"])
def test_accum3d_composite_non_pow2(kind):
    """accum3d consumes the composite walk unchanged at non-pow2 nb."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import simplex_kernels as K

    n, rho = 12, 2  # nb = 6: hmap resolves to composite
    x = jax.random.randint(jax.random.PRNGKey(0), (n,) * 3, 0, 9).astype(
        jnp.int32
    )
    got = np.asarray(K.accum3d(x, rho=rho, kind=kind))
    mask = np.indices((n,) * 3).sum(0) < n
    assert np.array_equal(got[mask], np.asarray(x)[mask] + 1)
    assert np.array_equal(got[~mask], np.asarray(x)[~mask])


def test_accum_md_composite_non_pow2_m4():
    """accum_md at m=4 on a non-pow2 block count goes through composite."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import simplex_kernels as K

    n, rho = 6, 1
    x = jax.random.randint(jax.random.PRNGKey(1), (n,) * 4, 0, 9).astype(
        jnp.int32
    )
    got = np.asarray(K.accum_md(x, rho=rho, kind="hmap"))
    mask = np.indices((n,) * 4).sum(0) < n
    assert np.array_equal(got[mask], np.asarray(x)[mask] + 1)
    assert np.array_equal(got[~mask], np.asarray(x)[~mask])


def test_composite_map_helper_roundtrip():
    """Direct composite_map use (strict coords) covers T^m(n) once."""
    m, n = 3, 10
    pieces = decompose_simplex(m, n)
    total = composite_grid_size(m, n)
    out = composite_map(pieces, m, np.arange(total))
    coords = np.stack([np.asarray(c) for c in out[:-1]], axis=1)
    v = np.asarray(out[-1])
    pts = coords[v]
    assert (pts >= 0).all() and (pts.sum(axis=1) < n).all()
    assert len(set(map(tuple, pts.tolist()))) == len(pts) == simplex_volume(n, m)
