"""Extended substrate tests: elastic re-sharding, gradient compression
with error feedback, watchdog restart (simulated node failure)."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointing as C
from repro.distributed.compression import (
    compress_bf16,
    compress_int8,
    decompress_int8,
    init_error_state,
)
from repro.distributed.fault_tolerance import Heartbeat, watchdog_restart


def test_elastic_reshard_roundtrip():
    """A checkpoint saved from one layout restores onto another mesh
    (global arrays are mesh-independent); values must be identical."""
    tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones((8,))}
    with tempfile.TemporaryDirectory() as d:
        C.save(d, 1, tree)
        if jax.device_count() >= 4:
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.launch.mesh import make_mesh

            mesh = make_mesh((2, 2), ("data", "model"))
            shard = {
                "w": NamedSharding(mesh, P("data", "model")),
                "b": NamedSharding(mesh, P("model")),
            }
            got, _ = C.restore_latest(d, tree, shard)
            assert got["w"].sharding.spec == P("data", "model")
        else:
            got, _ = C.restore_latest(d, tree)
        np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


def test_bf16_compression_error_feedback_unbiased():
    """With error feedback, the *accumulated* compressed signal tracks
    the true gradient sum (bias does not grow with steps)."""
    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(key, (256,)) * 1e-3}
    err = init_error_state(g)
    acc_true = np.zeros(256)
    acc_comp = np.zeros(256)
    for i in range(50):
        gi = {"w": g["w"] * (1 + 0.01 * i)}
        comp, err = compress_bf16(gi, err)
        acc_true += np.asarray(gi["w"])
        acc_comp += np.asarray(comp["w"], dtype=np.float32)
    resid = np.abs(acc_true - acc_comp).max()
    single_step_err = np.abs(
        np.asarray(g["w"]) - np.asarray(g["w"]).astype(np.float16)
    ).max()
    assert resid < 10 * max(single_step_err, 1e-5)  # no error accumulation


def test_int8_compression_roundtrip():
    key = jax.random.PRNGKey(1)
    g = {"w": jax.random.normal(key, (128, 4))}
    err = init_error_state(g)
    comp, err = compress_int8(g, err)
    deq = decompress_int8(comp)
    rel = float(jnp.abs(deq["w"] - g["w"]).max() / jnp.abs(g["w"]).max())
    assert rel < 0.02  # absmax int8: ~1/127 resolution
    # 4x wire reduction
    assert comp["w"][0].dtype == jnp.int8


def test_watchdog_restart_resumes_from_checkpoint():
    """Simulated node failure: the run crashes twice mid-training; the
    watchdog resumes from the latest checkpoint and finishes."""
    with tempfile.TemporaryDirectory() as d:
        state = {"calls": 0}

        def train_fn(resume_step):
            state["calls"] += 1
            step = resume_step or 0
            while step < 10:
                step += 1
                if step % 4 == 0:
                    C.save(d, step, {"step": jnp.asarray(step)})
                if state["calls"] < 3 and step == 4 * state["calls"] + 1:
                    raise RuntimeError("simulated node failure")

        restarts = watchdog_restart(train_fn, d, max_restarts=5)
        assert restarts == 2
        assert C.latest_step(d) == 8


def test_heartbeat_stale_detection():
    import time

    with tempfile.TemporaryDirectory() as d:
        hb0 = Heartbeat(d, 0)
        hb1 = Heartbeat(d, 1)
        hb0.beat()
        hb1.beat()
        assert Heartbeat.stale_hosts(d, timeout_s=5.0) == []
        time.sleep(0.05)
        hb0.beat()
        assert Heartbeat.stale_hosts(d, timeout_s=0.04) == [1]
