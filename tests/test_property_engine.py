"""Hypothesis property tests for the engine's new bodies (dev extra).

Invariants for the O(1)-effort body registrations the engine unlocked
(``edm3d`` / ``edm_md`` / ``ca_md``):

* **kind-swap consistency** — the schedule kind changes the grid walk,
  never the answer: integer bodies (CA) are bit-identical across every
  registered kind, float bodies (EDM) are bit-identical too because the
  per-tile compute depends only on the tile's coordinates, not the walk
  order (disjoint writes);
* **permutation consistency** — the EDM pair sum and the CA neighbour
  count are symmetric in the cell coordinates, so transposing the output
  by any axis permutation is a no-op on the (symmetric) m >= 3 domain;
* **split invariance** — element-local bodies launched per composite
  piece produce exactly the single-launch answer.

Gated behind the dev-extra skip in ``tests/conftest.py`` — deterministic
spot checks of the same invariants run unconditionally in
``tests/test_engine_parity.py``.
"""

import itertools

import numpy as np
import pytest

from conftest import require_dev_extra

require_dev_extra("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.kernels import engine as E
from repro.kernels import ref as R

_KINDS = {
    3: ["hmap", "octant", "bb", "table", "composite"],
    4: ["hmap", "bb", "table", "composite"],
}
_NS = {3: [4, 8, 12], 4: [4, 6, 8]}


def _points(seed, n):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, 3), jnp.float32)


def _state(seed, m, n):
    u = jax.random.uniform(jax.random.PRNGKey(seed), (n,) * m)
    return ((u < 0.4).astype(jnp.int32)) * R.simplex_mask(m, n, jnp.int32)


@given(m=st.sampled_from([3, 4]), seed=st.integers(0, 2**16), data=st.data())
@settings(max_examples=10, deadline=None)
def test_edm_md_kind_swap_consistent(m, seed, data):
    n = data.draw(st.sampled_from(_NS[m]))
    p = _points(seed, n)
    outs = [
        np.asarray(E.edm_md(p, m, rho=2, kind=kind)) for kind in _KINDS[m]
    ]
    for kind, o in zip(_KINDS[m][1:], outs[1:]):
        assert np.array_equal(outs[0], o), kind


@given(m=st.sampled_from([3, 4]), seed=st.integers(0, 2**16), data=st.data())
@settings(max_examples=10, deadline=None)
def test_ca_md_kind_swap_consistent(m, seed, data):
    n = data.draw(st.sampled_from(_NS[m]))
    s = _state(seed, m, n)
    outs = [
        np.asarray(E.ca_md(s, rho=2, kind=kind)) for kind in _KINDS[m]
    ]
    for kind, o in zip(_KINDS[m][1:], outs[1:]):
        assert np.array_equal(outs[0], o), kind


@given(m=st.sampled_from([3, 4]), seed=st.integers(0, 2**16), data=st.data())
@settings(max_examples=10, deadline=None)
def test_edm_md_permutation_consistent(m, seed, data):
    n = data.draw(st.sampled_from(_NS[m]))
    perm = data.draw(
        st.sampled_from(list(itertools.permutations(range(m)))[1:])
    )
    p = _points(seed, n)
    out = np.asarray(E.edm_md(p, m, rho=2, kind="table"))
    np.testing.assert_allclose(
        out, out.transpose(perm), rtol=1e-5, atol=1e-6
    )


@given(m=st.sampled_from([3, 4]), seed=st.integers(0, 2**16), data=st.data())
@settings(max_examples=10, deadline=None)
def test_ca_md_permutation_consistent(m, seed, data):
    n = data.draw(st.sampled_from(_NS[m]))
    perm = data.draw(
        st.sampled_from(list(itertools.permutations(range(m)))[1:])
    )
    s = _state(seed, m, n)
    # symmetric input -> symmetric output (integer CA: exact)
    s_sym = jnp.asarray(
        np.minimum(np.asarray(s), np.asarray(s).transpose(perm))
    )
    out = np.asarray(E.ca_md(s_sym, rho=2, kind="table"))
    assert np.array_equal(out, out.transpose(perm))


@given(m=st.sampled_from([3, 4]), seed=st.integers(0, 2**16), data=st.data())
@settings(max_examples=10, deadline=None)
def test_edm_md_split_invariant(m, seed, data):
    n = data.draw(st.sampled_from([6, 12]))
    p = _points(seed, n)
    a = np.asarray(E.edm_md(p, m, rho=2, kind="composite", split=False))
    b = np.asarray(E.edm_md(p, m, rho=2, kind="composite", split=True))
    assert np.array_equal(a, b)


@given(seed=st.integers(0, 2**16), data=st.data())
@settings(max_examples=10, deadline=None)
def test_edm3d_matches_oracle(seed, data):
    n = data.draw(st.sampled_from(_NS[3]))
    p = _points(seed, n)
    got = np.asarray(E.edm3d(p, rho=2, kind="table"))
    want = np.asarray(R.edm3d(p))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
