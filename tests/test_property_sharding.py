"""Hypothesis property tests for simplex sharding (dev extra, ISSUE 8).

Invariants of the fold partition and its sharded CA executor:

* **disjoint cover** — for any (S, k), the k shards' step ranges
  partition ``range(S)`` exactly, each shard is <= 2 contiguous
  ranges, and shard sizes differ by at most one (information-theoretic
  optimum);
* **skew bound** — ``shard_skew <= ceil(S/k)/(S/k) <= 1 + k/S`` for
  m in {2, 3, 4}, k in {2, 4, 8}, pow2 and non-pow2 n, and <= 1.05
  whenever S >= 20k (the acceptance regime);
* **bit-exact execution** — the sharded CA (per-shard engine launches
  + ownership-mask stitching) equals the single-device engine result
  bit-for-bit for random states, dimensions, and shard counts.

Gated behind the dev-extra skip in ``tests/conftest.py`` —
deterministic spot checks of the same invariants run unconditionally
in ``tests/test_simplex_sharding.py``.
"""

import numpy as np

from conftest import require_dev_extra

require_dev_extra("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.kernels.ref as ref
from repro.core.schedule import SimplexSchedule, resolve_kind
from repro.distributed.simplex_sharding import (
    fold_partition,
    shard_schedules,
    shard_skew,
    sharded_ca,
)
from repro.kernels.ops import simplex_ca2d, simplex_ca_md

_NS = {2: [8, 12, 16, 20, 32], 3: [4, 6, 8, 12, 16], 4: [4, 6, 8]}


@settings(max_examples=60, deadline=None)
@given(S=st.integers(1, 2000), k=st.integers(1, 16))
def test_fold_partition_properties(S, k):
    if k > S:
        return
    shards = fold_partition(S, k)
    cover = [i for s in shards for a, b in s.ranges for i in range(a, b)]
    assert sorted(cover) == list(range(S))
    sizes = [s.steps for s in shards]
    assert max(sizes) - min(sizes) <= 1
    assert all(1 <= len(s.ranges) <= 2 for s in shards)


@settings(max_examples=40, deadline=None)
@given(
    m=st.sampled_from([2, 3, 4]),
    ni=st.integers(0, 4),
    k=st.sampled_from([2, 4, 8]),
)
def test_skew_bound(m, ni, k):
    n = _NS[m][ni % len(_NS[m])]
    kind = resolve_kind(m, n, "hmap" if m == 2 else "table")
    sched = SimplexSchedule(m, n, kind)
    if k > sched.steps:
        return
    sk = shard_skew(sched, k)
    S = sched.steps
    assert sk <= np.ceil(S / k) / (S / k) + 1e-12
    assert sk <= 1 + k / S + 1e-12
    if S >= 20 * k:
        assert sk <= 1.05


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([2, 3]),
    ni=st.integers(0, 3),
    k=st.sampled_from([2, 4]),
    seed=st.integers(0, 10_000),
)
def test_shard_cover_of_walk(m, ni, k, seed):
    ns = {2: [16, 24, 32], 3: [8, 12, 16]}[m]
    n = ns[ni % len(ns)]
    kind = resolve_kind(m, n, "hmap" if m == 2 else "table")
    base = SimplexSchedule(m, n, kind)
    subs = shard_schedules(base, k)
    tabs = np.concatenate([s.table() for s in subs])
    assert sorted(map(tuple, tabs.tolist())) == sorted(
        map(tuple, base.table().tolist())
    )


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([2, 3]),
    k=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 10_000),
)
def test_sharded_ca_bit_equals_engine(m, k, seed):
    n = 32 if m == 2 else 16
    rng = np.random.default_rng(seed)
    state = (rng.random((n,) * m) < 0.4).astype(np.int32)
    state = np.where(
        np.asarray(ref.simplex_mask(m, n)), state, 0
    ).astype(np.int32)
    kind = "hmap" if m == 2 else "table"
    if m == 2:
        want = np.asarray(simplex_ca2d(state, kind=kind))
    else:
        want = np.asarray(simplex_ca_md(state, kind=kind))
    got = np.asarray(sharded_ca(state, k, kind=kind))
    assert np.array_equal(want, got)
