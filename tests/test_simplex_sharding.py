"""Deterministic tests for multi-device simplex sharding (ISSUE 8).

Partition invariants (fold cover / balance / skew), the ShardSchedule
surface (table concat == base table), engine-executor and SPMD-executor
bit-exactness against the single-device engine, the engine's explicit
``schedule=`` override, and the odd-tile-count behaviors of
``folded_causal_pairs`` / ``flash_grid_steps``.

The SPMD tests require >= 4 devices and skip on single-device sessions
(CI runs them under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""

import jax
import numpy as np
import pytest

import repro.kernels.ref as ref
from repro.core.schedule import SimplexSchedule, folded_causal_pairs
from repro.distributed.simplex_sharding import (
    ShardedSimplexCA,
    ShardSchedule,
    fold_partition,
    shard_mesh,
    shard_schedules,
    shard_skew,
    sharded_ca,
    slab_skew,
)
from repro.kernels.engine import SimplexKernel
from repro.kernels.flash_attention import flash_grid_steps
from repro.kernels.ops import simplex_ca2d, simplex_ca_md

needs_devices = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >=4 devices (run under "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


# ---------------------------------------------------------------- partition


@pytest.mark.parametrize("S", [1, 2, 5, 6, 17, 36, 120, 136, 529])
@pytest.mark.parametrize("k", [1, 2, 3, 4, 8])
def test_fold_partition_disjoint_cover_and_balance(S, k):
    if k > S:
        with pytest.raises(ValueError):
            fold_partition(S, k)
        return
    shards = fold_partition(S, k)
    assert len(shards) == k
    cover = [i for s in shards for a, b in s.ranges for i in range(a, b)]
    assert sorted(cover) == list(range(S))
    assert len(cover) == len(set(cover))
    sizes = [s.steps for s in shards]
    assert max(sizes) - min(sizes) <= 1  # optimal balance
    for s in shards:
        assert 1 <= len(s.ranges) <= 2


def test_fold_partition_matches_folded_causal_pairs():
    # k = S/2 reduces the general fold to the m=2 pair partition.
    S = 8
    shards = fold_partition(S, S // 2)
    pairs = folded_causal_pairs(S)
    for shard, (i, j) in zip(shards, pairs.tolist()):
        got = sorted(x for a, b in shard.ranges for x in range(a, b))
        assert got == sorted([i, j])


@pytest.mark.parametrize("m,ns", [(2, (16, 32, 64, 128, 256)),
                                  (3, (8, 16, 32, 64))])
@pytest.mark.parametrize("k", [2, 4, 8])
def test_shard_skew_bound(m, ns, k):
    # acceptance criterion: skew <= 1.05 for m in {2,3}, n <= 256.
    for n in ns:
        kind = "hmap" if m == 2 else "table"
        sched = SimplexSchedule(m, n, kind)
        sk = shard_skew(sched, k)
        assert sk <= 1.05, (m, n, k, sk)
        # and the fold is information-theoretically optimal:
        S = sched.steps
        assert sk <= np.ceil(S / k) / (S / k) + 1e-12


def test_slab_baseline_is_worse():
    # the naive equal-thickness slab split carries the ~m x imbalance
    # the fold removes (the contrast SHARD_SKEW rows record).
    assert slab_skew(2, 64, 8) > 1.5
    assert slab_skew(3, 32, 8) > 2.0
    base = SimplexSchedule(3, 32, "table")
    assert shard_skew(base, 8) < 1.01 < slab_skew(3, 32, 8)


# ------------------------------------------------------------ ShardSchedule


@pytest.mark.parametrize("m,n,kind", [
    (2, 16, "hmap"), (2, 16, "rb"), (2, 12, "composite"),
    (3, 8, "table"), (3, 8, "octant"), (3, 12, "composite"),
    (4, 4, "table"),
])
@pytest.mark.parametrize("k", [2, 4])
def test_shard_tables_cover_base(m, n, kind, k):
    base = SimplexSchedule(m, n, kind)
    subs = shard_schedules(base, k)
    assert sum(s.steps for s in subs) == base.steps
    tabs = np.concatenate([s.table() for s in subs])
    assert sorted(map(tuple, tabs.tolist())) == sorted(
        map(tuple, base.table().tolist())
    )


def test_owned_block_masks_are_disjoint_and_cover():
    base = SimplexSchedule(3, 8, "table")
    subs = shard_schedules(base, 4)
    masks = [s.owned_block_mask() for s in subs]
    total = np.zeros_like(masks[0], dtype=np.int32)
    for msk in masks:
        total += msk.astype(np.int32)
    domain = np.asarray(ref.simplex_mask(3, 8))
    assert np.array_equal(total == 1, domain)
    assert np.all(total <= 1)


def test_empty_shard_rejected():
    base = SimplexSchedule(3, 4, "table")  # 20 steps
    with pytest.raises(ValueError):
        shard_schedules(base, 21)


# ----------------------------------------------------- engine schedule= path


def test_engine_explicit_schedule_accum():
    # one shard's accum touches exactly its owned blocks.
    base = SimplexSchedule(3, 4, "table")
    subs = shard_schedules(base, 2)
    rho = 2
    n = base.n * rho
    outs = []
    for sh in subs:
        kern = SimplexKernel("accum", 3, rho=rho, kind="table", schedule=sh)
        outs.append(np.asarray(kern(np.zeros((n,) * 3, np.int32))))
    merged = sum(outs)
    full = np.asarray(
        SimplexKernel("accum", 3, rho=rho, kind="table")(
            np.zeros((n,) * 3, np.int32)
        )
    )
    assert np.array_equal(merged, full)


def test_engine_explicit_schedule_validates_shape():
    base = SimplexSchedule(3, 4, "table")
    sh = shard_schedules(base, 2)[0]
    kern = SimplexKernel("accum", 3, rho=2, schedule=sh)
    with pytest.raises(ValueError):  # nb mismatch: n=16 -> nb=8 != 4
        kern(np.zeros((16, 16, 16), np.int32))


# ------------------------------------------------------------- sharded CA


def _random_state(m, n, seed):
    rng = np.random.default_rng(seed)
    s = (rng.random((n,) * m) < 0.4).astype(np.int32)
    return np.where(np.asarray(ref.simplex_mask(m, n)), s, 0).astype(np.int32)


@pytest.mark.parametrize("k", [2, 4])
def test_sharded_ca_m3_engine_bit_exact(k):
    n = 16
    state = _random_state(3, n, 0)
    want = np.asarray(simplex_ca_md(state, kind="table"))
    got = np.asarray(sharded_ca(state, k, kind="table"))
    assert np.array_equal(want, got)


def test_sharded_ca_m2_engine_bit_exact():
    n = 32
    state = _random_state(2, n, 1)
    want = np.asarray(simplex_ca2d(state, kind="hmap"))
    got = np.asarray(sharded_ca(state, 4, kind="hmap"))
    assert np.array_equal(want, got)


def test_sharded_ca_multi_step():
    n = 16
    state = _random_state(3, n, 2)
    want = state
    for _ in range(3):
        want = np.asarray(simplex_ca_md(want, kind="table"))
    got = np.asarray(sharded_ca(state, 4, steps=3, kind="table"))
    assert np.array_equal(want, got)


@needs_devices
def test_sharded_ca_m3_spmd_bit_exact():
    k = min(4, jax.device_count())
    n = 16
    mesh = shard_mesh(k)
    state = _random_state(3, n, 3)
    want = np.asarray(simplex_ca_md(state, kind="table"))
    runner = ShardedSimplexCA(3, n, k, kind="table", mesh=mesh)
    got = np.asarray(runner.step(state, executor="spmd"))
    assert np.array_equal(want, got)


@needs_devices
def test_sharded_ca_m2_spmd_periodic_bit_exact():
    k = min(4, jax.device_count())
    n = 32
    mesh = shard_mesh(k)
    state = _random_state(2, n, 4)
    want = np.asarray(simplex_ca2d(state, kind="hmap"))
    runner = ShardedSimplexCA(2, n, k, kind="hmap", mesh=mesh)
    got = np.asarray(runner.step(state, executor="spmd"))
    assert np.array_equal(want, got)


@needs_devices
def test_engine_executor_with_mesh_placement():
    k = min(4, jax.device_count())
    n = 16
    state = _random_state(3, n, 5)
    want = np.asarray(simplex_ca_md(state, kind="table"))
    got = np.asarray(
        sharded_ca(state, k, kind="table", mesh=shard_mesh(k))
    )
    assert np.array_equal(want, got)


def test_shard_mesh_too_few_devices():
    with pytest.raises(ValueError):
        shard_mesh(jax.device_count() + 1)


# -------------------------------------------------------- odd tile counts


def test_folded_causal_pairs_odd_self_pairs_middle():
    pairs = folded_causal_pairs(5)
    assert pairs.tolist() == [[0, 4], [1, 3], [2, 2]]
    flat = sorted(set(pairs.ravel().tolist()))
    assert flat == [0, 1, 2, 3, 4]


def test_folded_causal_pairs_even_unchanged():
    assert folded_causal_pairs(4).tolist() == [[0, 3], [1, 2]]


def test_folded_causal_pairs_rejects_nonpositive():
    with pytest.raises(ValueError):
        folded_causal_pairs(0)


def test_flash_grid_steps_odd_self_pair_fold():
    # ISSUE 9: odd tile counts fold through the self-pair middle walk
    # (mirroring folded_causal_pairs) instead of raising.
    assert flash_grid_steps(5, "folded") == 18  # ceil(5/2) * (5+1)
    assert flash_grid_steps(3, "folded") == 8
    assert flash_grid_steps(5, "bb") == 25
    assert flash_grid_steps(4, "folded") == 10
    with pytest.raises(ValueError):
        flash_grid_steps(0, "folded")
    with pytest.raises(ValueError):
        flash_grid_steps(4, "zigzag")


def test_flash_attention_odd_tiles_runs():
    from repro.kernels.flash_attention import flash_attention
    from repro.models.attention import chunked_causal_attention

    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 1, 24, 8), jax.numpy.float32)
    k = jax.random.normal(ks[1], (1, 1, 24, 8), jax.numpy.float32)
    v = jax.random.normal(ks[2], (1, 1, 24, 8), jax.numpy.float32)
    got = flash_attention(q, k, v, kind="folded", block_q=8, block_kv=8)
    want = chunked_causal_attention(q, k, v, chunk=8)
    assert np.array_equal(np.asarray(got), np.asarray(want))
