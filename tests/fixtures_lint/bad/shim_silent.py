"""Fixture: deprecated shims that break the warn-and-delegate contract."""

from repro.kernels import engine


def silent_shim(x):
    """Deprecated: use engine.accum instead."""
    # Violation: delegates but never emits a DeprecationWarning.
    return engine.accum(x)


def warning_reimplementor(x):
    """Deprecated: use engine.accum instead."""
    import warnings

    warnings.warn("use engine.accum", DeprecationWarning, stacklevel=2)
    # Violation: warns but reimplements (no delegating return).
    out = x + 1
    return out


class SilentShimClass:
    """Deprecated thin shim that forgets to warn."""

    def __init__(self, n):
        # Violation: deprecated class whose __init__ never warns.
        self.n = n
