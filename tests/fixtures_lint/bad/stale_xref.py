"""Fixture: cross-references a DESIGN.md section that does not exist.

The schedule layer is documented in DESIGN.md §99 (stale — violation).
"""
