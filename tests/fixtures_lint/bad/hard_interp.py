"""Fixture: pins interpret=True instead of deferring to policy.py."""

from repro.kernels import engine


def hardcoded(x):
    # Violation: hardcodes the interpret mode (fixable to None).
    return engine.accum(x, rho=2, kind="bb", interpret=True)
