"""Fixture: constructs pl.pallas_call outside the engine front door."""

import jax
from jax.experimental import pallas as pl


def rogue_launch(kernel, out_shape):
    # Violation: must route through kernels.engine.pallas_launch.
    return pl.pallas_call(
        kernel, out_shape=jax.ShapeDtypeStruct(out_shape, "int32")
    )
