"""Fixture: tile constants violating the Mosaic 8x128 contract."""

# Violation: 12 is not a multiple of the 8-row sublane.
ATTN_BLOCKS = (128, 64, 12)

# Violation: compiled block shapes need lane % 128 == 0.
OUT_TILE_SHAPE = (8, 100)
