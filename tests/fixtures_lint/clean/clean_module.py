"""Fixture: a module every AST pass accepts (see DESIGN.md §2.3).

A correct deprecation shim, aligned tile constants, policy-resolved
interpret mode, and no pallas_call construction.
"""

import warnings

from repro.kernels import engine

GOOD_BLOCKS = (128, 64, 32, 16, 8)
GOOD_TILE_SHAPE = (8, 128)


def good_shim(x):
    """Deprecated: use engine.accum instead."""
    warnings.warn(
        "good_shim is deprecated; use engine.accum",
        DeprecationWarning,
        stacklevel=2,
    )
    return engine.accum(x, interpret=None)
