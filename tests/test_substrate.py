"""Substrate: optimizer convergence, data determinism, checkpoint
fault tolerance, mixer train/decode consistency, roofline cost model."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointing as C
from repro.data.pipeline import SyntheticLM, host_shard
from repro.optim.optimizer import clip_by_global_norm, make_optimizer, warmup_cosine

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_converges(kind):
    params = {"a": jax.random.normal(KEY, (16, 8)), "b": {"c": jnp.ones((8,))}}
    opt = make_optimizer(kind, warmup_cosine(1e-2, 10, 200))

    def lossf(p):
        return jnp.sum((p["a"] @ p["b"]["c"] - 1.0) ** 2)

    st = opt.init(params)
    l0 = float(lossf(params))
    p = params
    for i in range(40):
        g = jax.grad(lossf)(p)
        p, st = opt.update(g, st, p, jnp.asarray(i))
    assert float(lossf(p)) < 0.3 * l0


def test_grad_clip():
    g = {"x": jnp.full((4,), 100.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["x"])) - 1.0) < 1e-5
    assert float(gn) == pytest.approx(200.0)


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 32))}
    opt = make_optimizer("adafactor", warmup_cosine(1e-3, 1, 10))
    st = opt.init(params)
    assert st["f"]["w"]["vr"].shape == (64,)
    assert st["f"]["w"]["vc"].shape == (32,)


def test_data_deterministic_and_restartable():
    d1 = SyntheticLM(1000, 32, 8, seed=3)
    d2 = SyntheticLM(1000, 32, 8, seed=3)
    b5a = d1.batch_at(5)
    for s in [0, 1, 2]:
        d2.batch_at(s)  # different call history
    b5b = d2.batch_at(5)
    assert np.array_equal(np.asarray(b5a["tokens"]), np.asarray(b5b["tokens"]))
    b6 = d1.batch_at(6)
    assert not np.array_equal(np.asarray(b5a["tokens"]), np.asarray(b6["tokens"]))


def test_host_shard_partitions_batch():
    d = SyntheticLM(100, 16, 8)
    b = d.batch_at(0)
    parts = [host_shard(b, i, 4)["tokens"] for i in range(4)]
    assert all(p.shape[0] == 2 for p in parts)
    assert np.array_equal(
        np.concatenate([np.asarray(p) for p in parts]), np.asarray(b["tokens"])
    )


def test_checkpoint_roundtrip_and_atomicity():
    tree = {
        "params": {"w": jnp.arange(12.0).reshape(3, 4),
                   "stack": (jnp.ones((2, 2)), jnp.zeros(3))},
        "opt": {"m": {"w": jnp.full((3, 4), 0.5)}},
    }
    with tempfile.TemporaryDirectory() as d:
        C.save(d, 3, tree)
        C.save(d, 5, tree)
        assert C.list_steps(d) == [3, 5]
        got, step = C.restore_latest(d, tree)
        assert step == 5
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # simulated crash mid-save: .tmp is never picked up
        os.makedirs(os.path.join(d, "step_00000009.tmp"))
        assert C.latest_step(d) == 5
        # corrupt LATEST pointer: falls back to newest complete
        open(os.path.join(d, "LATEST"), "w").write("garbage")
        assert C.latest_step(d) == 5


def test_train_restart_is_bit_exact():
    """Kill/restart mid-run reproduces the uninterrupted run exactly —
    the fault-tolerance contract (stateless data + atomic checkpoints)."""
    from repro.launch.train import main as train_main

    with tempfile.TemporaryDirectory() as d:
        args = ["--arch", "xlstm-350m", "--smoke", "--seq", "32",
                "--batch", "4", "--lr", "1e-3"]
        full = train_main(args + ["--steps", "6"])
        # interrupted run: 3 steps + checkpoint, then resume to 6 (the
        # LR schedule horizon must match the full run's)
        train_main(args + ["--steps", "3", "--schedule-steps", "6",
                           "--ckpt-dir", d, "--ckpt-every", "3"])
        resumed = train_main(args + ["--steps", "6", "--ckpt-dir", d,
                                     "--resume", "--ckpt-every", "100"])
        np.testing.assert_allclose(full[-1], resumed[-1], rtol=1e-5)


def test_hlo_cost_model_loop_awareness():
    from repro.roofline.hlo_cost import analyze_hlo

    single = 2 * 128**3
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    r = analyze_hlo(jax.jit(f).lower(x, w).compile().as_text())
    assert r["flops"] == pytest.approx(12 * single)

    def g(w, x):  # grad+remat: fwd + recompute + 2x bwd per step
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(jax.checkpoint(body), x, None, length=6)
        return jnp.sum(y)

    r = analyze_hlo(jax.jit(jax.grad(g)).lower(w, x).compile().as_text())
    assert r["flops"] == pytest.approx(24 * single)


def test_roofline_terms_shape():
    from repro.roofline.analysis import roofline_terms

    rec = {
        "n_chips": 256, "flops": 1e18, "bytes_accessed": 1e15,
        "collectives": {"wire_bytes_per_chip": 1e11},
        "mode": "train", "params": int(1e9), "params_active": 1e9,
        "tokens": 1e6, "model_axis": 16, "microbatches": 1,
    }
    t = roofline_terms(rec)
    assert t["dominant"] in ("compute", "memory", "collective")
    assert t["compute_s"] == pytest.approx(1e18 / (256 * 197e12))
    assert 0 < t["useful_ratio"] <= 10
