"""Compiled-path tests (DESIGN.md §5).

Three layers:
  * index_map parity — the compiled (one-jit-program) evaluation of
    every registered schedule map visits exactly the host-built step
    list, for exhaustive small (m, n);
  * executor parity — the fused-XLA ACCUM executors match the numpy
    truth and the interpret-mode Pallas kernels, including the per-piece
    launch split of composite schedules;
  * policy — per-backend interpret resolution, the REPRO_INTERPRET
    override, and the compiled-tile alignment contract.
"""

import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

_REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]

from repro.core.schedule import SimplexSchedule, registered_kinds
from repro.kernels import simplex_kernels as K
from repro.kernels.compiled import (
    accum2d_compiled,
    accum3d_compiled,
    accum_md_compiled,
    schedule_coords_compiled,
)
from repro.kernels.policy import (
    aligned_rho,
    check_tile_alignment,
    default_interpret,
    resolve_interpret,
)

# (m, n) grid for the exhaustive index_map parity sweep: pow2 and
# non-pow2 sides so every kind's resolution (recursion, composite
# decomposition, table walk) is exercised.
_PARITY_MN = [(2, 4), (2, 8), (2, 6), (2, 12), (3, 4), (3, 8), (3, 6),
              (4, 4), (4, 6)]


def _constructible(m, n):
    out = []
    for kind in registered_kinds(m):
        try:
            SimplexSchedule(m, n, kind)
        except (ValueError, AssertionError):
            continue
        out.append(kind)
    return out


@pytest.mark.parametrize("m,n", _PARITY_MN)
def test_compiled_index_map_visits_host_step_list(m, n):
    """The jnp map, jit-compiled over all grid steps, equals .table()."""
    for kind in _constructible(m, n):
        sched = SimplexSchedule(m, n, kind)
        got = schedule_coords_compiled(m, n, kind)
        want = np.asarray(sched.table(), dtype=np.int64)
        assert got.shape == want.shape, (kind, got.shape, want.shape)
        assert np.array_equal(got.astype(np.int64), want), (
            f"compiled index_map diverges from host step list "
            f"(m={m}, n={n}, kind={kind})"
        )


def _tri2(n):
    return np.tri(n, dtype=np.int32)


def _simplex_md(m, n):
    ii = np.arange(n)
    g = np.zeros((n,) * m, dtype=np.int64)
    for ax in range(m):
        g = g + ii.reshape((1,) * ax + (n,) + (1,) * (m - 1 - ax))
    return (g < n).astype(np.int32)


@pytest.mark.parametrize("kind", ["hmap", "rb", "bb", "auto"])
def test_accum2d_compiled_parity(kind):
    n, rho = 32, 8
    x = np.arange(n * n, dtype=np.int32).reshape(n, n) % 97
    want = x + _tri2(n)
    got = np.asarray(accum2d_compiled(jnp.asarray(x), rho=rho, kind=kind))
    assert np.array_equal(got, want)
    if kind != "auto":
        interp = np.asarray(
            K.accum2d(jnp.asarray(x), rho=rho, kind=kind, interpret=True)
        )
        assert np.array_equal(got, interp)


@pytest.mark.parametrize(
    "m,n,rho,kind",
    [
        (3, 16, 4, "hmap"),
        (3, 16, 4, "octant"),
        (3, 16, 4, "bb"),
        (3, 16, 4, "table"),
        (3, 24, 4, "composite"),
        (3, 24, 4, "table"),
        (4, 8, 2, "hmap"),
        (4, 8, 2, "table"),
        (4, 12, 2, "composite"),
        (3, 16, 4, "auto"),
    ],
)
def test_accum_md_compiled_parity(m, n, rho, kind):
    x = (np.arange(n**m, dtype=np.int32).reshape((n,) * m)) % 53
    want = x + _simplex_md(m, n)
    got = np.asarray(accum_md_compiled(jnp.asarray(x), rho=rho, kind=kind))
    assert np.array_equal(got, want)


def test_accum3d_split_parity():
    """Per-piece launch split == single composite launch == compiled."""
    n, rho = 24, 4
    x = (np.arange(n**3, dtype=np.int32).reshape(n, n, n)) % 31
    want = x + _simplex_md(3, n)
    unsplit = np.asarray(
        K.accum3d(jnp.asarray(x), rho=rho, kind="composite", split=False)
    )
    split = np.asarray(
        K.accum3d(jnp.asarray(x), rho=rho, kind="composite", split=True)
    )
    comp = np.asarray(
        accum3d_compiled(jnp.asarray(x), rho=rho, kind="composite")
    )
    assert np.array_equal(unsplit, want)
    assert np.array_equal(split, want)
    assert np.array_equal(comp, want)


def test_accum_md_split_parity_m4():
    n, rho = 12, 2
    x = (np.arange(n**4, dtype=np.int32).reshape((n,) * 4)) % 19
    want = x + _simplex_md(4, n)
    for split in (False, True):
        got = np.asarray(
            K.accum_md(jnp.asarray(x), rho=rho, kind="composite",
                       split=split)
        )
        assert np.array_equal(got, want), f"split={split}"


def test_split_env_override(monkeypatch):
    """REPRO_SPLIT_PIECES forces the launch-split decision both ways."""
    from repro.autotune import should_split_pieces

    monkeypatch.setenv("REPRO_SPLIT_PIECES", "1")
    assert should_split_pieces(2, 10)
    monkeypatch.setenv("REPRO_SPLIT_PIECES", "0")
    assert not should_split_pieces(100, 10**9)


# -- policy -----------------------------------------------------------


def test_default_interpret_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_INTERPRET", "1")
    assert default_interpret() is True
    monkeypatch.setenv("REPRO_INTERPRET", "0")
    assert default_interpret() is False


def test_default_interpret_per_backend(monkeypatch):
    monkeypatch.delenv("REPRO_INTERPRET", raising=False)
    assert default_interpret("cpu") is True
    assert default_interpret("tpu") is False
    assert default_interpret("gpu") is False
    # this host's live backend resolves without error to a bool
    assert default_interpret() in (True, False)


def test_resolve_interpret_passthrough(monkeypatch):
    monkeypatch.delenv("REPRO_INTERPRET", raising=False)
    assert resolve_interpret(True, "tpu") is True
    assert resolve_interpret(False, "cpu") is False
    assert resolve_interpret(None, "cpu") is True
    assert resolve_interpret(None, "tpu") is False


def test_tile_alignment_contract():
    # interpret mode: anything goes
    check_tile_alignment((3, 5), interpret=True)
    # compiled mode: (8k, 128k) tiles pass, others raise
    check_tile_alignment((8, 128), interpret=False)
    check_tile_alignment((16, 256), interpret=False)
    check_tile_alignment((1, 8, 128), interpret=False)  # unit dims drop
    with pytest.raises(ValueError):
        check_tile_alignment((8, 100), interpret=False)
    with pytest.raises(ValueError):
        check_tile_alignment((5, 128), interpret=False)


def test_aligned_rho():
    assert aligned_rho(16, interpret=True) == 16
    assert aligned_rho(16, interpret=False) == 128
    assert aligned_rho(200, interpret=False) == 256


def test_no_hardcoded_interpret_true_in_kernels():
    """Migrated into the simplexlint registry (DESIGN.md §9)."""
    from repro.analysis import run_passes

    assert not run_passes(_REPO_ROOT, passes=["hardcoded-interpret"])


def test_no_pallas_call_outside_engine_and_compiled():
    """Migrated into the simplexlint registry (DESIGN.md §9)."""
    from repro.analysis import run_passes

    assert not run_passes(_REPO_ROOT, passes=["pallas-front-door"])
