"""SimplexSchedule subsystem invariants (exhaustive, no hypothesis).

Every registered (m, kind) schedule must *visit each simplex cell
exactly once* over its valid steps — the bijectivity contract the
kernels rely on — and the recursive m-map's measured waste must respect
the paper's asymptotic extra-space bound (Eq. 30 generalized) with a
finite-n allowance.
"""

import numpy as np
import pytest

from repro.core.general_m import alpha_extra_space, best_r_beta
from repro.core.schedule import (
    Schedule2D,
    SimplexSchedule,
    grid_steps,
    registered_kinds,
    resolve_kind,
)
from repro.core.simplex import simplex_volume

CASES = [
    (m, n, kind)
    for m, ns in [(2, [4, 16]), (3, [4, 8]), (4, [4, 8])]
    for n in ns
    for kind in registered_kinds(m)
]


def _in_domain(m, coords, n):
    """m=2 uses the matrix (col, row) lower-triangle convention
    {0 <= x <= y <= n-1} (causal attention tiles, |.| = tri(n));
    m >= 3 uses the standard simplex {x >= 0, sum(x) < n}."""
    if m == 2:
        return (
            (coords[:, 0] >= 0)
            & (coords[:, 0] <= coords[:, 1])
            & (coords[:, 1] < n)
        )
    return (coords >= 0).all(axis=1) & (coords.sum(axis=1) < n)


@pytest.mark.parametrize("m,n,kind", CASES)
def test_schedule_bijective_on_simplex(m, n, kind):
    """Valid steps cover the m-simplex exactly once; coords in-domain."""
    sched = SimplexSchedule(m, n, kind)
    tab = sched.table()
    assert tab.shape == (sched.steps, m + 1)
    assert sched.steps == int(np.prod(sched.grid))
    valid = tab[:, -1] == 1
    coords = tab[valid, :-1]
    assert _in_domain(m, coords, n).all()
    pts = set(map(tuple, coords.tolist()))
    assert len(pts) == len(coords) == sched.useful == simplex_volume(n, m)


@pytest.mark.parametrize("m,n,kind", CASES)
def test_schedule_map_dual_backend(m, n, kind):
    """The jax-traced map agrees with the host numpy walk table."""
    import jax.numpy as jnp

    sched = SimplexSchedule(m, n, kind)
    want = sched.table()
    lin = np.arange(sched.steps, dtype=np.int64)
    ws = []
    for g in sched.grid:
        ws.append(jnp.asarray(lin % g, dtype=jnp.int32))
        lin = lin // g
    if sched.needs_table:
        ws.append(jnp.asarray(sched.prefetch))
    out = sched.map(*ws)
    got = np.stack(
        [np.asarray(c, dtype=np.int64) for c in out[:-1]]
        + [np.asarray(out[-1]).astype(np.int64)],
        axis=1,
    )
    assert np.array_equal(got, want.astype(np.int64))


@pytest.mark.parametrize("m", [2, 3, 4, 5])
def test_recursive_waste_within_asymptotic_bound(m):
    """Measured waste of the (2, m) recursion stays within the Lemma 6.1
    asymptote + 25% finite-n allowance once n clears the tiny sizes."""
    inv_r, beta = best_r_beta(m, constructible=True)
    assert (inv_r, beta) == (2, m)
    bound = alpha_extra_space(m, inv_r, beta) + 0.25
    for n in (8, 16, 32):
        sched = SimplexSchedule(m, n, "hmap")
        assert sched.waste() <= bound, (m, n, sched.waste(), bound)
        assert sched.asymptotic_waste() == alpha_extra_space(m, inv_r, beta)


def test_m4_hmap_bijective_and_bounded():
    """The ISSUE acceptance shape: SimplexSchedule(4, n, 'hmap') is a
    bijection onto Delta^4 with waste <= alpha(4, 2, 4) + 25%."""
    n = 16
    sched = SimplexSchedule(4, n, "hmap")
    tab = sched.table()
    valid = tab[:, -1] == 1
    coords = tab[valid, :-1]
    assert _in_domain(4, coords, n).all()
    pts = set(map(tuple, coords.tolist()))
    assert len(pts) == simplex_volume(n, 4)
    assert sched.waste() <= alpha_extra_space(4, 2, 4) + 0.25


def test_registered_kinds_per_dimension():
    assert set(registered_kinds(2)) == {"hmap", "rb", "bb", "table", "composite"}
    assert set(registered_kinds(3)) == {
        "hmap", "octant", "bb", "table", "composite",
    }
    assert set(registered_kinds(4)) == {"hmap", "bb", "table", "composite"}
    with pytest.raises(ValueError):
        SimplexSchedule(2, 8, "octant")
    with pytest.raises(ValueError):
        SimplexSchedule(1, 8, "hmap")


def test_resolve_kind_fallbacks():
    # m=2: non-pow2 hmap -> rb (even) or bb (odd); odd rb -> bb
    # (the 2D kernels need a (w, h) grid, so m=2 keeps the single-map
    # fallbacks; the linear-grid composite kind serves m=2 analysis)
    assert resolve_kind(2, 6, "hmap") == "rb"
    assert resolve_kind(2, 7, "hmap") == "bb"
    assert resolve_kind(2, 7, "rb") == "bb"
    assert resolve_kind(2, 8, "hmap") == "hmap"
    # m>=3: non-pow2 recursion -> the general-n composite decomposition
    assert resolve_kind(3, 6, "octant") == "composite"
    assert resolve_kind(4, 10, "hmap") == "composite"
    assert resolve_kind(4, 16, "hmap") == "hmap"
    # explicit exact kinds pass through untouched
    assert resolve_kind(3, 6, "table") == "table"
    assert resolve_kind(4, 10, "composite") == "composite"


def test_grid_steps_delegates_across_dimensions():
    assert grid_steps(16, "hmap") == 8 * 17
    assert grid_steps(16, "bb", m=3) == 16**3
    assert grid_steps(16, "table", m=4) == simplex_volume(16, 4)
    # the paper's potential-speedup ordering: hmap beats bb for every m
    for m in (2, 3, 4):
        assert grid_steps(16, "bb", m=m) > grid_steps(16, "hmap", m=m)


def test_schedule2d_shim_deprecated_but_equivalent():
    with pytest.warns(DeprecationWarning):
        old = Schedule2D(8, "hmap")
    new = SimplexSchedule(2, 8, "hmap")
    assert old.grid == new.grid and old.steps == new.steps
    assert np.array_equal(old.table(), new.table())
