"""Hypothesis property tests for the system's invariants.

Invariants under test:
  * H(w) is injective and lands in the strict lower triangle for any
    power-of-two n and any in-range block coordinate;
  * inverse(H(w)) == w everywhere;
  * the inclusive-diagonal grid hits every tile exactly once (counted
    via random probes of the inverse direction);
  * the trapezoid decomposition covers any n >= 1 exactly;
  * the octant 3-simplex map is injective with valid cells inside T(n);
  * the folded causal schedule assigns every (q, kv <= q) pair exactly
    one grid step.
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis is a dev extra (pip install -e '.[dev]'); "
    "the deterministic schedule invariants run in tests/test_schedule.py",
)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import hmap as H
from repro.core.simplex import tet, tri
from repro.core.trapezoids import decompose, trapezoid_map

pow2 = st.integers(1, 12).map(lambda k: 1 << k)


@given(k=st.integers(1, 14), data=st.data())
@settings(max_examples=200, deadline=None)
def test_hmap2_point_properties(k, data):
    n = 1 << k
    wx = data.draw(st.integers(0, n // 2 - 1))
    wy = data.draw(st.integers(1, n - 1))
    x, y = H.hmap2(wx, wy)
    assert 0 <= x < y <= n - 1
    iwx, iwy = H.hmap2_inverse(x, y)
    assert (iwx, iwy) == (wx, wy)


@given(k=st.integers(1, 14), data=st.data())
@settings(max_examples=200, deadline=None)
def test_hmap2_inverse_total_on_triangle(k, data):
    """Every strict-lower point has a unique preimage in the grid."""
    n = 1 << k
    y = data.draw(st.integers(1, n - 1))
    x = data.draw(st.integers(0, y - 1))
    wx, wy = H.hmap2_inverse(x, y)
    assert 0 <= wx < n // 2 and 1 <= wy <= n - 1
    fx, fy = H.hmap2(wx, wy)
    assert (fx, fy) == (x, y)


@given(n=st.integers(1, 3000))
@settings(max_examples=80, deadline=None)
def test_trapezoid_cover_any_n(n):
    total = 0
    seen_rows = np.zeros(n, dtype=np.int64)
    for t in decompose(n):
        w, h = t.grid_shape
        wy, wx = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
        x, y, v = trapezoid_map(t, wx.ravel(), wy.ravel())
        x, y, v = np.asarray(x), np.asarray(y), np.asarray(v)
        x, y = x[v], y[v]
        assert ((0 <= x) & (x <= y) & (y <= n - 1)).all()
        np.add.at(seen_rows, y, 1)
        total += len(x)
    assert total == tri(n)
    assert np.array_equal(seen_rows, np.arange(1, n + 1))


@given(k=st.integers(1, 6), data=st.data())
@settings(max_examples=100, deadline=None)
def test_octant_cells_valid(k, data):
    n = 1 << k
    g = H.hmap3_octant_grid_size(n)
    i = data.draw(st.integers(0, g - 1))
    x, y, z, valid = H.hmap3_octant(np.asarray([i]), n)
    if valid[0]:
        assert x[0] >= 0 and y[0] >= 0 and z[0] >= 0
        assert x[0] + y[0] + z[0] < n


@given(k=st.integers(1, 10), data=st.data())
@settings(max_examples=150, deadline=None)
def test_folded_schedule_unique_step(k, data):
    """Each causal tile (q, kv<=q) is served by exactly one (p, j)."""
    nq = 2 << k  # even
    q = data.draw(st.integers(0, nq - 1))
    kv = data.draw(st.integers(0, q))
    # invert the fold: pair p serves q (first segment, j=kv<=p) if q=p;
    # or second segment with p = nq-1-q, j = p+1+kv
    if kv <= min(q, nq - 1 - q) and q <= nq // 2 - 1:
        p, j = q, kv
    else:
        p, j = nq - 1 - q, (nq - 1 - q) + 1 + kv
    assert 0 <= p < nq // 2 and 0 <= j <= nq
    second = j > p
    qq = nq - 1 - p if second else p
    kk = j - p - 1 if second else j
    assert (qq, kk) == (q, kv)


@given(v=st.integers(1, 2**31 - 1))
@settings(max_examples=300, deadline=None)
def test_pow2_floor_matches_bitlength(v):
    assert H.pow2_floor(v) == 1 << (int(v).bit_length() - 1)


@given(m=st.integers(2, 6), k=st.integers(1, 5), data=st.data())
@settings(max_examples=150, deadline=None)
def test_recursive_m_map_cells_valid(m, k, data):
    """Any valid cell of the general-m orthant recursion lands in T(n)."""
    n = 1 << k
    g = H.hmap_m_grid_size(n, m)
    i = data.draw(st.integers(0, g - 1))
    out = H.hmap_m_recursive(np.asarray([i]), n, m)
    coords, valid = out[:-1], out[-1]
    if valid[0]:
        assert all(c[0] >= 0 for c in coords)
        assert sum(c[0] for c in coords) < n
