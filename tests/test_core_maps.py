"""Core map correctness: H 2-simplex/3-simplex, RB, lambda, trapezoids,
general-m formulas — the paper's mathematical objects (Eqs. 4-31)."""

import numpy as np
import pytest

from repro.core import hmap as H
from repro.core import simplex as S
from repro.core.general_m import (
    alpha_extra_space,
    alpha_r_half_beta_2,
    n0_coverage,
    optimize_r_beta,
    potential_speedup,
    self_similar_volume,
)
from repro.core.maps_baseline import lambda_map2, lambda_map3, rb_map2
from repro.core.schedule import Schedule2D, folded_causal_pairs, grid_steps
from repro.core.trapezoids import decompose, trapezoid_map


@pytest.mark.parametrize("n", [2, 4, 8, 32, 128, 512])
def test_hmap2_strict_bijection(n):
    wy, wx = np.meshgrid(np.arange(1, n), np.arange(n // 2), indexing="ij")
    x, y = H.hmap2(wx.ravel(), wy.ravel())
    assert ((0 <= x) & (x < y) & (y <= n - 1)).all()
    assert len({(a, b) for a, b in zip(x.tolist(), y.tolist())}) == n * (n - 1) // 2


@pytest.mark.parametrize("n", [2, 4, 16, 64, 256])
def test_hmap2_full_zero_waste(n):
    """Grid (n/2, n+1) covers {x <= y <= n-1} exactly once — V(Pi) = tri(n)."""
    wy, wx = np.meshgrid(np.arange(n + 1), np.arange(n // 2), indexing="ij")
    x, y = H.hmap2_full(wx.ravel(), wy.ravel(), n)
    pts = set(zip(x.tolist(), y.tolist()))
    assert len(pts) == S.tri(n) == (n // 2) * (n + 1)
    assert all(0 <= a <= b <= n - 1 for a, b in pts)


@pytest.mark.parametrize("n", [4, 64, 1024])
def test_hmap2_inverse_roundtrip(n):
    wy, wx = np.meshgrid(np.arange(1, n), np.arange(n // 2), indexing="ij")
    x, y = H.hmap2(wx.ravel(), wy.ravel())
    iwx, iwy = H.hmap2_inverse(x, y)
    assert np.array_equal(iwx, wx.ravel()) and np.array_equal(iwy, wy.ravel())


def test_hmap2_jax_matches_numpy():
    import jax.numpy as jnp

    n = 64
    wy, wx = np.meshgrid(np.arange(n + 1), np.arange(n // 2), indexing="ij")
    xn, yn = H.hmap2_full(wx.ravel(), wy.ravel(), n)
    xj, yj = H.hmap2_full(jnp.asarray(wx.ravel()), jnp.asarray(wy.ravel()), n)
    assert np.array_equal(np.asarray(xj), xn)
    assert np.array_equal(np.asarray(yj), yn)


@pytest.mark.parametrize("n", [2, 4, 8, 16, 64])
def test_hmap3_octant_exact(n):
    g = H.hmap3_octant_grid_size(n)
    x, y, z, valid = H.hmap3_octant(np.arange(g), n)
    pts = set(
        zip(x[valid].tolist(), y[valid].tolist(), z[valid].tolist())
    )
    assert int(valid.sum()) == len(pts) == S.tet(n)
    assert all(a + b + c < n for a, b, c in pts)
    # overhead approaches ~20% (vs +500% for BB) — paper-spirit efficiency
    if n >= 32:
        assert g / S.tet(n) < 1.25


def test_hmap3_paper_literal_coverage_documented():
    """Eq. 26 under the literal reading: the calibration documented in
    DESIGN.md — injectivity holds for most of its image but the printed
    equation covers only ~30% of T(n) (figure-dependent geometry)."""
    n = 16
    w, h, d = H.hmap3_paper_grid_shape(n)
    wz, wy, wx = np.meshgrid(
        np.arange(d), np.arange(n // 2), np.arange(n // 2), indexing="ij"
    )
    x, y, z, valid = H.hmap3_paper(wx.ravel(), wy.ravel(), wz.ravel(), n)
    pts = [p for p, v in zip(zip(x.tolist(), y.tolist(), z.tolist()), valid) if v]
    frac = len(set(pts)) / S.tet(n)
    assert 0.2 < frac < 0.5  # calibrated: literal text is under-specified


@pytest.mark.parametrize("n", [4, 16, 256])
def test_rb_bijection(n):
    wy, wx = np.meshgrid(np.arange(n + 1), np.arange(n // 2), indexing="ij")
    x, y = rb_map2(wx.ravel(), wy.ravel(), n)
    pts = set(zip(x.tolist(), y.tolist()))
    assert len(pts) == S.tri(n)
    assert all(0 <= a <= b <= n - 1 for a, b in pts)


def test_lambda_map2_exact_integer_corrected():
    w = np.arange(0, 500_000, dtype=np.int64)
    x, y = lambda_map2(w)
    assert np.array_equal(y * (y + 1) // 2 + x, w)
    assert ((0 <= x) & (x <= y)).all()


def test_lambda_map3_bijection():
    w = np.arange(0, S.tet(48), dtype=np.int64)
    x, y, z = lambda_map3(w)
    pts = set(zip(np.asarray(x).tolist(), np.asarray(y).tolist(),
                  np.asarray(z).tolist()))
    assert len(pts) == S.tet(48)
    s = np.asarray(x) + np.asarray(y) + np.asarray(z)
    assert s.max() < 48 and np.asarray(x).min() >= 0


@pytest.mark.parametrize("n", [3, 5, 27, 100, 777, 1000, 1023])
def test_trapezoids_cover_general_n(n):
    covered = set()
    for t in decompose(n):
        w, h = t.grid_shape
        wy, wx = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
        x, y, v = trapezoid_map(t, wx.ravel(), wy.ravel())
        for a, b, ok in zip(x.tolist(), y.tolist(), np.asarray(v).tolist()):
            if ok:
                assert 0 <= a <= b <= n - 1
                covered.add((a, b))
    assert len(covered) == S.tri(n)


def test_trapezoid_set_is_small():
    # §4.2: the set is <= log2(n) pieces, typically tiny with threshold
    for n in [100, 1000, 65535]:
        assert len(decompose(n)) <= max(int(np.log2(n)) + 1, 1)


def test_bb_overhead_formula():
    # Eq. 6: m! - 1
    assert S.bb_overhead(2) == 1.0
    assert S.bb_overhead(3) == 5.0
    assert S.bb_overhead(4) == 23.0


def test_alpha_matches_paper_values():
    # Lemma 6.1 examples: m=4 -> 5/7, m=5 -> 3, m=7 -> 39
    assert abs(alpha_r_half_beta_2(4) - 5.0 / 7.0) < 1e-12
    assert abs(alpha_r_half_beta_2(5) - 3.0) < 1e-12
    assert abs(alpha_r_half_beta_2(7) - 39.0) < 1e-12
    # efficient for m = 2, 3 (zero extra space)
    assert alpha_r_half_beta_2(2) == 0.0
    assert alpha_r_half_beta_2(3) == 0.0


def test_self_similar_volume_closed_form():
    # Eq. 13 / 22: V(S_n^2) = n(n-1)/2 ; V(S_n^3) = (n^3 - n)/6
    for n in [4, 16, 256]:
        assert self_similar_volume(n, 2) == n * (n - 1) // 2
        assert self_similar_volume(n, 3) == (n**3 - n) // 6


def test_optimize_r_beta_feasible_m4():
    cands = optimize_r_beta(4, max_inv_r=6, max_beta=12)
    assert cands, "Thm 6.2: feasible sets exist for m=4"
    best = cands[0]
    assert best.alpha <= 5.0 / 7.0 + 1e-9
    assert potential_speedup(4, best.inv_r, best.beta) > 10


def test_schedule_grid_steps_ratios():
    # the MAP-test speedups are the BB/steps ratios
    n = 128
    assert grid_steps(n, "bb") / grid_steps(n, "hmap") == pytest.approx(
        2.0, rel=0.03
    )
    assert grid_steps(n, "bb", m=3) / grid_steps(n, "table", m=3) > 5.5
    assert grid_steps(n, "bb", m=3) / grid_steps(n, "octant", m=3) > 4.5


def test_folded_pairs_balanced():
    n = 64
    pairs = folded_causal_pairs(n)
    work = pairs.sum(1) + 2  # (i+1) + (n-i) per pair
    assert (work == work[0]).all()  # equal triangle area per shard
