"""Differential parity harness: engine vs frozen legacy vs numpy oracle.

Three independent implementations of every workload are compared:

* the dimension-generic ``SimplexKernel`` engine (``kernels/engine.py``)
  — the implementation under test;
* the frozen hand-rolled kernels (``kernels/legacy.py``) — the original
  per-(body, dimension) ``pallas_call``s, kept verbatim precisely so
  this suite is not comparing the engine with itself;
* the pure-jnp oracles (``kernels/ref.py``) — the semantic ground truth.

Integer bodies (ACCUM, CA, MAP) must agree **bit for bit**; EDM at m=2
is also bit-exact against legacy (identical op order per pair), while
the m >= 3 EDM bodies (no legacy twin) are checked against the oracle to
float tolerance.  The sweep covers pow2 and non-pow2 n and every
schedule kind registered for the dimension; ``REPRO_PARITY_QUICK=1``
(the CI quick mode) trims it to one pow2 size and the analytic kinds.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import engine as E
from repro.kernels import legacy as L
from repro.kernels import ref as R

_QUICK = os.environ.get("REPRO_PARITY_QUICK", "").strip() in ("1", "true")

# (m, n, rho): pow2 and non-pow2 sides per dimension.
_SIZES = {
    2: [(16, 4), (24, 4)],
    3: [(8, 2), (12, 2)],
    4: [(8, 2), (6, 2)],
}
# Every kind the kernels accept per dimension ('composite' at m=2 is
# engine-only: the legacy 2D kernels launch a (w, h) grid).
_KINDS = {
    2: ["hmap", "rb", "bb", "composite"],
    3: ["hmap", "octant", "bb", "table", "composite"],
    4: ["hmap", "bb", "table", "composite"],
}
_LEGACY_2D_KINDS = ("hmap", "rb", "bb")

if _QUICK:
    _SIZES = {m: sizes[:1] for m, sizes in _SIZES.items()}
    _KINDS = {
        2: ["hmap", "bb"],
        3: ["hmap", "table"],
        4: ["hmap", "composite"],
    }


def _cases():
    return [
        (m, n, rho, kind)
        for m, sizes in _SIZES.items()
        for n, rho in sizes
        for kind in _KINDS[m]
    ]


def _ids(case):
    m, n, rho, kind = case
    return f"m{m}-n{n}-{kind}"


_CASES = _cases()


def _mask(m, n):
    return np.asarray(R.simplex_mask(m, n))


def _legacy_supports(m, kind):
    return m != 2 or kind in _LEGACY_2D_KINDS


# -- MAP --------------------------------------------------------------


@pytest.mark.parametrize("kind", _KINDS[2], ids=str)
@pytest.mark.parametrize("nb", [4] if _QUICK else [4, 6])
def test_map_parity_2d(nb, kind):
    from repro.core.schedule import resolve_kind

    got = np.asarray(E.map_table(nb, m=2, kind=kind))
    # both kernels apply the kernel-facing kind resolution (hmap -> rb
    # for non-pow2 m=2); the oracle table must be built the same way
    want = np.asarray(R.map_table_2d(nb, resolve_kind(2, nb, kind)))
    assert np.array_equal(got, want)
    if _legacy_supports(2, kind):
        assert np.array_equal(got, np.asarray(L.map2d(nb, kind)))


@pytest.mark.parametrize("m,nb", [(3, 4), (4, 2)])
def test_map_parity_md(m, nb):
    from repro.core.schedule import SimplexSchedule, resolve_kind

    for kind in _KINDS[m]:
        got = np.asarray(E.map_table(nb, m=m, kind=kind))
        sched = SimplexSchedule(m, nb, resolve_kind(m, nb, kind))
        want = np.asarray(sched.table())
        assert np.array_equal(got, want), kind


# -- ACCUM ------------------------------------------------------------


@pytest.mark.parametrize("case", _CASES, ids=_ids)
def test_accum_parity(case):
    m, n, rho, kind = case
    x = jnp.asarray(
        (np.arange(n**m, dtype=np.int32).reshape((n,) * m)) % 97
    )
    got = np.asarray(E.accum(x, rho=rho, kind=kind))
    msk = _mask(m, n)
    # oracle: +1 on the domain, input preserved off it
    want = np.asarray(R.accum_md(x))
    assert np.array_equal(got[msk == 1], want[msk == 1])
    assert np.array_equal(got[msk == 0], np.asarray(x)[msk == 0])
    # legacy: bit-equal everywhere (same trash-tile write discipline)
    if _legacy_supports(m, kind):
        legacy_fn = {2: L.accum2d, 3: L.accum3d}.get(m, L.accum_md)
        assert np.array_equal(
            got, np.asarray(legacy_fn(x, rho=rho, kind=kind))
        )


@pytest.mark.parametrize("m", [2, 3, 4])
def test_accum_split_invariance(m):
    n, rho = {2: (24, 4), 3: (12, 2), 4: (6, 2)}[m]
    x = jnp.asarray((np.arange(n**m, dtype=np.int32).reshape((n,) * m)) % 53)
    a = np.asarray(E.accum(x, rho=rho, kind="composite", split=False))
    b = np.asarray(E.accum(x, rho=rho, kind="composite", split=True))
    assert np.array_equal(a, b)


# -- EDM --------------------------------------------------------------


@pytest.mark.parametrize("case", _CASES, ids=_ids)
def test_edm_parity(case):
    m, n, rho, kind = case
    p = jax.random.normal(jax.random.PRNGKey(n + m), (n, 3), jnp.float32)
    if m == 2:
        got = np.asarray(E.edm2d(p, rho=rho, kind=kind))
    else:
        got = np.asarray(E.edm_md(p, m, rho=rho, kind=kind))
    msk = _mask(m, n)
    want = np.asarray(R.edm_md(p, m))
    if m == 2:
        # single pair, identical op order -> bit-exact vs the oracle
        assert np.array_equal(got, want)
        if _legacy_supports(2, kind):
            assert np.array_equal(
                got, np.asarray(L.edm2d(p, rho=rho, kind=kind))
            )
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # off-domain cells hold the zeros seed exactly
    assert np.array_equal(got[msk == 0], np.zeros_like(got[msk == 0]))


def test_edm3d_is_edm_md_m3():
    p = jax.random.normal(jax.random.PRNGKey(0), (8, 3), jnp.float32)
    assert np.array_equal(
        np.asarray(E.edm3d(p, kind="table")),
        np.asarray(E.edm_md(p, 3, kind="table")),
    )


def test_edm_md_rejects_m2():
    p = jnp.zeros((8, 3), jnp.float32)
    with pytest.raises(ValueError):
        E.edm_md(p, 2)


# -- CA ---------------------------------------------------------------


@pytest.mark.parametrize("case", _CASES, ids=_ids)
def test_ca_parity(case):
    m, n, rho, kind = case
    key = jax.random.PRNGKey(n * m)
    s = (jax.random.uniform(key, (n,) * m) < 0.4).astype(jnp.int32)
    s = s * R.simplex_mask(m, n, jnp.int32)
    got = np.asarray(E.ca(s, rho=rho, kind=kind))
    msk = _mask(m, n)
    want = np.asarray(
        R.ca2d_step(s) if m == 2 else R.ca_md_step(s)
    )
    assert np.array_equal(got[msk == 1], want[msk == 1])
    assert np.array_equal(got[msk == 0], np.asarray(s)[msk == 0])
    if _legacy_supports(m, kind) and m in (2, 3):
        legacy_fn = {2: L.ca2d, 3: L.ca3d}[m]
        assert np.array_equal(
            got, np.asarray(legacy_fn(s, rho=rho, kind=kind))
        )


def test_ca_md_rejects_m2():
    with pytest.raises(ValueError):
        E.ca_md(jnp.zeros((8, 8), jnp.int32))


def test_ca_kind_swap_consistency():
    """Schedule kind changes the walk, never the answer (integers ->
    bit-exact).  The hypothesis sweep in test_property_engine.py widens
    this; the deterministic spot check always runs."""
    n = 8
    s = (jax.random.uniform(jax.random.PRNGKey(9), (n, n, n)) < 0.4).astype(
        jnp.int32
    )
    outs = [
        np.asarray(E.ca_md(s, kind=kind)) for kind in _KINDS[3]
    ]
    for o in outs[1:]:
        assert np.array_equal(outs[0], o)


# -- deprecation shims ------------------------------------------------


def test_legacy_wrappers_warn_and_delegate():
    """Every simplex_kernels entry point warns once and still answers."""
    from repro.kernels import simplex_kernels as K

    n = 8
    x2 = jnp.asarray(np.arange(n * n, dtype=np.int32).reshape(n, n))
    x3 = jnp.asarray(np.arange(n**3, dtype=np.int32).reshape(n, n, n))
    p = jax.random.normal(jax.random.PRNGKey(0), (n, 3), jnp.float32)
    s2 = (jax.random.uniform(jax.random.PRNGKey(1), (n, n)) < 0.4).astype(
        jnp.int32
    )
    s3 = (jax.random.uniform(jax.random.PRNGKey(2), (n, n, n)) < 0.4).astype(
        jnp.int32
    )
    calls = [
        (K.map2d, (4,), {}, lambda: E.map_table(4, m=2)),
        (K.accum2d, (x2,), {"rho": 4}, lambda: E.accum(x2, rho=4)),
        (K.edm2d, (p,), {"rho": 4}, lambda: E.edm2d(p, rho=4)),
        (K.ca2d, (s2,), {"rho": 4}, lambda: E.ca(s2, rho=4)),
        (K.accum3d, (x3,), {"rho": 2}, lambda: E.accum(x3, rho=2)),
        (K.ca3d, (s3,), {"rho": 2}, lambda: E.ca(s3, rho=2)),
        (K.accum_md, (x3,), {"rho": 2}, lambda: E.accum_md(x3, rho=2)),
    ]
    for fn, args, kwargs, engine_fn in calls:
        with pytest.warns(DeprecationWarning):
            got = fn(*args, **kwargs)
        assert np.array_equal(np.asarray(got), np.asarray(engine_fn())), (
            fn.__name__
        )


def test_grid_steps_shims_warn():
    from repro.kernels import simplex_kernels as K

    with pytest.warns(DeprecationWarning):
        assert K.grid_steps_2d(8, "hmap") == E.grid_steps(8, "hmap", m=2)
    with pytest.warns(DeprecationWarning):
        assert K.grid_steps_3d(8, "table") == E.grid_steps(8, "table", m=3)


def test_schedule2d_shim_warns():
    from repro.core.schedule import Schedule2D

    with pytest.warns(DeprecationWarning):
        Schedule2D(8, "hmap")


# -- engine surface ---------------------------------------------------


def test_registered_bodies():
    assert set(E.registered_bodies()) >= {"accum", "edm", "ca", "map"}


def test_engine_xla_executor_parity():
    n = 16
    x = jnp.asarray(np.arange(n * n, dtype=np.int32).reshape(n, n))
    a = np.asarray(E.accum(x, kind="hmap", executor="pallas"))
    b = np.asarray(E.accum(x, kind="hmap", executor="xla"))
    assert np.array_equal(a, b)
