"""Flash-attention parity suite (ISSUE 9, DESIGN.md §8).

Differential triangle: the Pallas flash kernel (both simplex schedules,
interpret mode on this host) vs the chunked XLA realization vs a
float64 numpy softmax oracle — across even/odd tile counts, GQA
ratios, head dims, additive bias and segment masking.  Flash and
chunked share tile size, reduction order and f32 accumulation, so the
suite asserts BIT-parity between them (the acceptance bar for swapping
the serving hot path), and oracle-closeness at f32 tolerance.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import (
    attn_apply,
    attn_init,
    chunked_causal_attention,
    simplex_attention,
)


def _qkv(b, hq, hkv, s, d, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    return q, k, v


def np_causal_attention(q, k, v, bias=None, segment_ids=None):
    """Float64 softmax oracle (GQA-aware, optional bias/segments)."""
    q = np.asarray(q, np.float64)
    k = np.asarray(k, np.float64)
    v = np.asarray(v, np.float64)
    b, hq, s, d = q.shape
    g = hq // k.shape[1]
    kq = np.repeat(k, g, axis=1)
    vq = np.repeat(v, g, axis=1)
    sc = np.einsum("bhqd,bhkd->bhqk", q, kq) * d**-0.5
    mask = np.tril(np.ones((s, s), bool))[None, None]
    if bias is not None:
        sc = sc + np.asarray(bias, np.float64)
    if segment_ids is not None:
        seg = np.asarray(segment_ids)
        mask = mask & (seg[:, None, :, None] == seg[:, None, None, :])
    sc = np.where(mask, sc, -np.inf)
    sc = sc - sc.max(-1, keepdims=True)
    p = np.exp(sc)
    tot = p.sum(-1, keepdims=True)
    p = np.where(tot > 0, p / np.where(tot == 0, 1.0, tot), 0.0)
    return np.einsum("bhqk,bhkd->bhqd", p, vq)


@pytest.mark.parametrize("s,block", [(64, 16), (48, 16)])  # nq 4 | 3
@pytest.mark.parametrize("gqa", [1, 4])
@pytest.mark.parametrize("d", [64, 128])
def test_flash_vs_chunked_vs_oracle(s, block, gqa, d):
    hq = 4
    q, k, v = _qkv(2, hq, hq // gqa, s, d, seed=s + gqa + d)
    want = np_causal_attention(q, k, v)
    ch = chunked_causal_attention(q, k, v, chunk=block)
    np.testing.assert_allclose(np.asarray(ch), want, atol=2e-5, rtol=2e-5)
    for kind in ("folded", "bb"):
        fl = flash_attention(q, k, v, kind=kind, block_q=block, block_kv=block)
        # same tiling + reduction order + f32 accumulation -> bit-equal
        assert np.array_equal(np.asarray(fl), np.asarray(ch)), kind


def test_flash_additive_bias_matches_oracle():
    s, block = 64, 32
    q, k, v = _qkv(2, 4, 1, s, 64, seed=7)
    bias = jax.random.normal(jax.random.PRNGKey(8), (2, 1, s, s), jnp.float32)
    want = np_causal_attention(q, k, v, bias=np.asarray(bias))
    for kind in ("folded", "bb"):
        got = flash_attention(
            q, k, v, bias=bias, kind=kind, block_q=block, block_kv=block
        )
        np.testing.assert_allclose(
            np.asarray(got), want, atol=2e-5, rtol=2e-5
        )


def test_flash_segment_masking_matches_oracle():
    s, block = 64, 16
    q, k, v = _qkv(1, 4, 2, s, 64, seed=9)
    seg = jnp.asarray(
        np.repeat(np.arange(4), s // 4)[None].repeat(1, 0), jnp.int32
    )
    want = np_causal_attention(q, k, v, segment_ids=np.asarray(seg))
    for kind in ("folded", "bb"):
        got = flash_attention(
            q, k, v, segment_ids=seg, kind=kind,
            block_q=block, block_kv=block,
        )
        np.testing.assert_allclose(
            np.asarray(got), want, atol=2e-5, rtol=2e-5
        )


def test_simplex_attention_dispatch_bit_parity(monkeypatch):
    # the dispatch's flash result must bit-match chunked at the tile the
    # decision picked — the hot-path swap is invisible numerically.
    monkeypatch.setenv("REPRO_AUTOTUNE_DISABLE", "1")
    from repro.autotune import choose_attn_impl

    q, k, v = _qkv(4, 4, 1, 64, 16, seed=1)
    dec = choose_attn_impl(64, 4, 16)
    assert dec.impl == "flash" and dec.kind == "folded"
    fl = simplex_attention(q, k, v, impl="flash")
    ch = chunked_causal_attention(q, k, v, chunk=dec.block_q)
    assert np.array_equal(np.asarray(fl), np.asarray(ch))


def test_simplex_attention_mla_shape_falls_back(monkeypatch):
    # v_head_dim != qk head dim (MLA): flash cannot map it; the dispatch
    # must return the chunked result, not raise.
    monkeypatch.setenv("REPRO_AUTOTUNE_DISABLE", "1")
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 4, 64, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 4, 64, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 4, 64, 48), jnp.float32)  # dv != d
    got = simplex_attention(q, k, v, impl="flash")
    want = chunked_causal_attention(q, k, v)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_simplex_attention_rejects_unknown_impl():
    q, k, v = _qkv(1, 2, 2, 16, 8)
    with pytest.raises(ValueError, match="impl"):
        simplex_attention(q, k, v, impl="mystery")


class _Cfg:
    d_model = 64
    n_heads = 4
    n_kv_heads = 1
    hd = 16
    rope_theta = 10_000.0
    mrope_sections = None
    attention_chunk = 512
    attention_schedule = "folded"
    attention_impl = "auto"


def test_attn_apply_decode_matches_prefill(monkeypatch):
    # decode (KV-cache strip path) must agree with the flash prefill on
    # the same token: run prefill over s+1 tokens, and separately
    # prefill s then decode token s against the cache.
    monkeypatch.setenv("REPRO_AUTOTUNE_DISABLE", "1")
    cfg = _Cfg()
    s = 64
    p = attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, s + 1, cfg.d_model))
    pos = jnp.arange(s + 1)[None].repeat(2, 0)

    full, _ = attn_apply(p, cfg, x, pos, mode="train")
    _, cache = attn_apply(p, cfg, x[:, :s], pos[:, :s], mode="prefill")
    dec, _ = attn_apply(
        p, cfg, x[:, s:], pos[:, s:], mode="decode", cache=cache
    )
    np.testing.assert_allclose(
        np.asarray(dec[:, 0]), np.asarray(full[:, s]), atol=2e-5, rtol=2e-5
    )


@pytest.mark.parametrize("kind", ["folded", "bb"])
def test_flash_grad_matches_chunked(kind):
    # training goes through jax.grad: the custom-VJP backward (XLA
    # reference attention) must agree with AD through the chunked walk.
    q, k, v = _qkv(2, 4, 2, 48, 32, seed=7)

    def flash_loss(q, k, v):
        out = flash_attention(q, k, v, kind=kind, block_q=16, block_kv=16)
        return (out * out).sum()

    def chunk_loss(q, k, v):
        out = chunked_causal_attention(q, k, v, chunk=16)
        return (out * out).sum()

    gf = jax.grad(flash_loss, argnums=(0, 1, 2))(q, k, v)
    gc = jax.grad(chunk_loss, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gc):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5
        )


def test_flash_grad_bias_and_segments():
    # bias cotangent flows; int segment ids take a float0 cotangent
    # (i.e. grad simply works in a packed-training step).
    q, k, v = _qkv(2, 4, 1, 32, 16, seed=8)
    bias = jax.random.normal(jax.random.PRNGKey(9), (2, 1, 32, 32))
    seg = jnp.concatenate(
        [jnp.zeros((2, 16), jnp.int32), jnp.ones((2, 16), jnp.int32)], axis=1
    )

    def loss(q, k, v, bias):
        out = flash_attention(
            q, k, v, bias=bias, segment_ids=seg,
            kind="folded", block_q=16, block_kv=16,
        )
        return (out * out).sum()

    grads = jax.jit(jax.grad(loss, argnums=(0, 1, 2, 3)))(q, k, v, bias)
    for g in grads:
        assert bool(jnp.isfinite(g).all())
    assert grads[3].shape == bias.shape
