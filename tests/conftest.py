"""Shared pytest config.  NOTE: device count is NOT forced here — smoke
tests see 1 device; multi-device tests skip unless the session provides
devices (scripts/run_tests.sh runs the sharding module with XLA_FLAGS)."""

import pytest


def require_dev_extra(name: str):
    """Dev-extra gate: skip the calling module unless ``name`` imports.

    Property-test modules (hypothesis-driven) call this at import time so
    the deterministic suites stay runnable on minimal installs::

        hyp = require_dev_extra("hypothesis")
    """
    return pytest.importorskip(
        name,
        reason=f"{name} is a dev extra (pip install -e '.[dev]'); "
        "the deterministic equivalents run in the non-property suites",
    )
