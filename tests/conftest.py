"""Shared pytest config.  NOTE: device count is NOT forced here — smoke
tests see 1 device; multi-device tests skip unless the session provides
devices (scripts/run_tests.sh runs the sharding module with XLA_FLAGS)."""
