"""Extended model coverage: M-RoPE, EP MoE parity, xLSTM decode
continuity, trapezoid fallback behaviour, folded-attention gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.ALL import REDUCED
from repro.kernels import ref as R
from repro.models.attention import chunked_causal_attention
from repro.models.layers import mrope, rope

KEY = jax.random.PRNGKey(0)


def test_mrope_reduces_to_rope_on_diagonal_positions():
    """With (t,h,w) all equal to the 1-D position, M-RoPE == RoPE."""
    b, h, s, d = 2, 4, 16, 32
    x = jax.random.normal(KEY, (b, h, s, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    pos3 = jnp.broadcast_to(pos[..., None], (b, s, 3))
    got = mrope(x, pos3, (8, 4, 4), theta=1e4)
    want = rope(x, pos, theta=1e4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_mrope_sections_use_distinct_streams():
    b, h, s, d = 1, 2, 8, 32
    x = jax.random.normal(KEY, (b, h, s, d))
    pos3a = jnp.stack([jnp.arange(s), jnp.zeros(s), jnp.zeros(s)], -1)[None]
    pos3b = jnp.stack([jnp.arange(s), jnp.arange(s), jnp.zeros(s)], -1)[None]
    a = mrope(x, pos3a.astype(jnp.int32), (8, 4, 4))
    bb = mrope(x, pos3b.astype(jnp.int32), (8, 4, 4))
    assert float(jnp.abs(a - bb).max()) > 1e-3  # h-stream matters


def test_folded_attention_grads_match_bb():
    """The simplex schedule must be gradient-equivalent to BB."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 16))
    k = jax.random.normal(ks[1], (1, 2, 128, 16))
    v = jax.random.normal(ks[2], (1, 2, 128, 16))

    def loss(sched):
        def f(q, k, v):
            o = chunked_causal_attention(q, k, v, chunk=32, schedule=sched)
            return jnp.sum(o**2)
        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    gf = loss("folded")
    gb = loss("bb")
    for a, b in zip(gf, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)


def test_xlstm_decode_continues_prefill_exactly():
    from repro.models.xlstm import mlstm_apply, mlstm_init

    cfg = REDUCED["xlstm-350m"]().replace(param_dtype="float32",
                                          act_dtype="float32")
    p = mlstm_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 48, cfg.d_model))
    full, _ = mlstm_apply(p, cfg, x, mode="train")
    o_pref, st = mlstm_apply(p, cfg, x[:, :32], mode="prefill")
    outs = [o_pref]
    for t in range(32, 48):
        o, st = mlstm_apply(p, cfg, x[:, t : t + 1], mode="decode", cache=st)
        outs.append(o)
    stitched = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stitched), np.asarray(full),
                               rtol=3e-3, atol=3e-4)


def test_kernel_nonpow2_fallback_correct():
    """nb=6 tiles (not pow2): the kernel must still be exact (RB fallback)."""
    from repro.kernels import simplex_kernels as K

    n, rho = 48, 8
    x = jax.random.randint(KEY, (n, n), 0, 100).astype(jnp.int32)
    got = K.accum2d(x, rho=rho, kind="hmap")
    want = R.accum2d(x)
    m = np.asarray(R.tril_mask(n))
    assert np.array_equal(np.asarray(got)[m], np.asarray(want)[m])
    # and the schedule it fell back to is zero-waste
    assert K.grid_steps_2d(6, "hmap") == 6 // 2 * 7


@pytest.mark.skipif(jax.device_count() < 4, reason="needs 4 devices")
def test_moe_ep_equals_tp_on_mesh():
    from repro.launch.mesh import make_mesh
    from repro.models.moe import moe_apply, moe_init

    mesh = make_mesh((2, 2), ("data", "model"))
    cfg = REDUCED["qwen2-moe-a2.7b"]().replace(
        param_dtype="float32", act_dtype="float32"
    )
    p = moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 16, cfg.d_model))
    out_tp, _ = jax.jit(lambda p, x: moe_apply(p, cfg, x, mesh))(p, x)
    cfg_ep = cfg.replace(moe_impl="ep")
    out_ep, _ = jax.jit(lambda p, x: moe_apply(p, cfg_ep, x, mesh))(p, x)
    np.testing.assert_allclose(np.asarray(out_ep), np.asarray(out_tp),
                               rtol=2e-4, atol=2e-5)


def test_trapezoid_grid_cells_near_optimal():
    from repro.core.simplex import tri
    from repro.core.trapezoids import total_grid_cells

    # §4.2: waste stays small for arbitrary n (threshold-bounded set)
    for n in [100, 1000, 4097, 30000]:
        waste = total_grid_cells(n) / tri(n) - 1
        assert waste < 0.02, (n, waste)
