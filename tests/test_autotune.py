"""Autotuner tests (DESIGN.md §5): decision quality, disk-cache hit
path, stale invalidation, and the measured-row overlay."""

import json

import pytest

from repro.autotune import (
    candidate_kinds,
    choose_kind,
    should_split_pieces,
)
from repro.autotune import tuner as T
from repro.core.schedule import registered_kinds, resolve_kind


@pytest.fixture()
def env(tmp_path, monkeypatch):
    """Hermetic tuner env: private cache + bench artifact paths."""
    cache = tmp_path / "autotune.json"
    bench = tmp_path / "BENCH_maps.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(cache))
    monkeypatch.setenv("REPRO_BENCH_ARTIFACT", str(bench))
    monkeypatch.delenv("REPRO_AUTOTUNE_DISABLE", raising=False)
    return {"cache": cache, "bench": bench}


def _bench_artifact(rows):
    return {"schema": "bench-maps/v2", "rows": rows}


def _row(m, n_elems, kind, us, steps, compiled=True, backend="cpu"):
    return {
        "test": f"ACCUM{m}D" if m > 2 else "ACCUM", "map": kind, "m": m,
        "n": n_elems, "grid_steps": steps, "waste": 0.0,
        "us_per_call": us, "backend": backend, "jax_version": "x",
        "compiled": compiled,
    }


def test_decision_is_concrete_and_cached(env):
    d = choose_kind(3, 8, backend="cpu")
    assert d.kind in registered_kinds(3)
    assert d.source in ("model", "measured")
    assert d.scores_us  # per-candidate scores recorded
    data = json.loads(env["cache"].read_text())
    assert data["schema"] == T.CACHE_SCHEMA
    assert "m=3,n=8,backend=cpu" in data["entries"]

    d2 = choose_kind(3, 8, backend="cpu")
    assert d2.source == "cache"
    assert d2.kind == d.kind


def test_cache_hit_does_not_recompute(env, monkeypatch):
    d = choose_kind(2, 16, backend="cpu")

    def boom(*a, **k):
        raise AssertionError("scored on a cache hit")

    monkeypatch.setattr(T, "_model_scores", boom)
    monkeypatch.setattr(T, "_measured_scores", boom)
    d2 = choose_kind(2, 16, backend="cpu")
    assert d2.source == "cache" and d2.kind == d.kind


def test_refresh_bypasses_cache(env, monkeypatch):
    choose_kind(2, 16, backend="cpu")
    d = choose_kind(2, 16, backend="cpu", refresh=True)
    assert d.source != "cache"


def test_stale_on_bench_artifact_change(env):
    choose_kind(3, 8, backend="cpu")
    env["bench"].write_text(json.dumps(_bench_artifact([])))
    d = choose_kind(3, 8, backend="cpu")
    assert d.source != "cache"  # fingerprint changed -> recompute
    assert choose_kind(3, 8, backend="cpu").source == "cache"


def test_stale_on_jax_version_change(env, monkeypatch):
    choose_kind(3, 8, backend="cpu")
    monkeypatch.setattr(T, "_jax_version", lambda: "999.0.0")
    d = choose_kind(3, 8, backend="cpu")
    assert d.source != "cache"


def test_measured_rows_win(env):
    """Measured ranking kicks in when every candidate has a row."""
    from repro.core.schedule import SimplexSchedule

    kinds = candidate_kinds(3, 8)
    assert "bb" in kinds
    rows = [
        _row(3, 32, k, us=(0.001 if k == "bb" else 1000.0),
             steps=SimplexSchedule(3, 8, k).steps)
        for k in kinds
    ]
    env["bench"].write_text(json.dumps(_bench_artifact(rows)))
    d = choose_kind(3, 8, backend="cpu")
    assert d.kind == "bb"
    assert d.source == "measured"


def test_partial_measured_coverage_keeps_model_ranking(env):
    """One measured row must not distort the ranking: mixing a
    whole-executor wall-clock with model overhead estimates would
    penalize exactly the kind that got benchmarked."""
    env["bench"].write_text(json.dumps(_bench_artifact([
        _row(3, 32, "bb", us=0.001, steps=8**3),
    ])))
    d = choose_kind(3, 8, backend="cpu")
    assert d.source == "model"


def test_interpret_rows_are_ignored(env):
    env["bench"].write_text(json.dumps(_bench_artifact([
        _row(3, 32, "bb", us=0.001, steps=8**3, compiled=False),
    ])))
    d = choose_kind(3, 8, backend="cpu")
    assert d.source == "model"  # emulator timing must not override


def test_other_backend_rows_are_ignored(env):
    env["bench"].write_text(json.dumps(_bench_artifact([
        _row(3, 32, "bb", us=0.001, steps=8**3, backend="tpu"),
    ])))
    d = choose_kind(3, 8, backend="cpu")
    assert d.source == "model"


def test_candidate_kinds_m2_excludes_linear_grid_kinds():
    for n in (8, 16, 12):
        ks = candidate_kinds(2, n)
        assert ks
        assert "table" not in ks and "composite" not in ks


def test_resolve_kind_auto_is_concrete(env):
    for m, n in [(2, 16), (2, 12), (3, 8), (3, 6), (4, 4)]:
        kind = resolve_kind(m, n, "auto", backend="cpu")
        assert kind != "auto"
        assert kind in registered_kinds(m)


def test_disable_env_skips_cache(env, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_DISABLE", "1")
    d = choose_kind(3, 8, backend="cpu")
    assert d.source != "cache"
    assert not env["cache"].exists()


def test_should_split_pieces_threshold(monkeypatch):
    monkeypatch.delenv("REPRO_SPLIT_PIECES", raising=False)
    assert not should_split_pieces(2, 10**7)  # too few pieces
    assert not should_split_pieces(10, 100)  # chain cheaper than launches
    assert should_split_pieces(10, 10**7)


# ---------------------------------------------------------------------------
# Attention-impl decisions (ISSUE 9, DESIGN.md §8)
# ---------------------------------------------------------------------------


def _attn_row(kind, us, steps, compiled=True, backend="tpu"):
    return {
        "test": "ATTN", "map": kind, "m": 2, "n": 32, "grid_steps": steps,
        "us_per_call": us, "backend": backend, "jax_version": "x",
        "compiled": compiled,
    }


def test_attn_decision_serve_shape_is_folded_flash(env, monkeypatch):
    monkeypatch.delenv("REPRO_ATTN_STEP_CAP", raising=False)
    d = T.choose_attn_impl(64, 4, 16, backend="cpu")
    assert (d.impl, d.kind) == ("flash", "folded")
    assert d.block_q == 32 and 64 % d.block_q == 0
    assert d.source == "model" and set(d.scores_us) == {
        "folded", "bb", "chunked"
    }
    data = json.loads(env["cache"].read_text())
    assert "attn,s=64,h=4,d=16,backend=cpu" in data["entries"]
    assert T.choose_attn_impl(64, 4, 16, backend="cpu").source == "cache"


def test_attn_decision_compiled_backend_prefers_folded(env):
    d = T.choose_attn_impl(4096, 32, 128, backend="tpu")
    assert (d.impl, d.kind, d.block_q) == ("flash", "folded", 128)


def test_attn_interpret_step_cap_falls_back(env, monkeypatch):
    monkeypatch.delenv("REPRO_ATTN_STEP_CAP", raising=False)
    d = T.choose_attn_impl(4096, 32, 128, backend="cpu")
    assert (d.impl, d.source) == ("chunked", "fallback")
    monkeypatch.setenv("REPRO_ATTN_STEP_CAP", "10000000")
    d2 = T.choose_attn_impl(4096, 32, 128, backend="cpu", refresh=True)
    assert d2.impl == "flash"


def test_attn_unmappable_seq_falls_back(env):
    d = T.choose_attn_impl(100, 4, 16, backend="cpu")  # no tile divides 100
    assert (d.impl, d.kind, d.block_q) == ("chunked", "chunked", 0)
    assert d.source == "fallback"


def test_attn_compiled_lane_alignment_falls_back(env):
    # head_dim 64 misses the 8x128 Mosaic lane contract on compiled
    # backends -> chunked (interpret hosts may still map it).
    d = T.choose_attn_impl(4096, 8, 64, backend="tpu")
    assert (d.impl, d.source) == ("chunked", "fallback")


def test_attn_measured_rows_win(env):
    from repro.kernels.flash_attention import flash_grid_steps

    heads = 32
    steps_f = heads * flash_grid_steps(32, "folded")
    steps_b = heads * flash_grid_steps(32, "bb")
    env["bench"].write_text(json.dumps(_bench_artifact([
        _attn_row("folded", 500.0, steps_f),
        _attn_row("bb", 600.0, steps_b),
        _attn_row("chunked", 10.0, steps_f),
    ])))
    d = T.choose_attn_impl(4096, heads, 128, backend="tpu")
    assert (d.impl, d.kind, d.source) == ("chunked", "chunked", "measured")


def test_attn_partial_measured_coverage_keeps_model(env):
    env["bench"].write_text(json.dumps(_bench_artifact([
        _attn_row("chunked", 10.0, 1000),
    ])))
    d = T.choose_attn_impl(4096, 32, 128, backend="tpu")
    assert d.source == "model" and d.kind == "folded"


def test_attn_interpret_measured_rows_are_ignored(env):
    rows = [_attn_row(k, 1.0, 1000, compiled=False)
            for k in ("folded", "bb", "chunked")]
    env["bench"].write_text(json.dumps(_bench_artifact(rows)))
    d = T.choose_attn_impl(4096, 32, 128, backend="tpu")
    assert d.source == "model"


def test_attn_block_q_shapes():
    assert T.attn_block_q(64, 16, backend="cpu") == 32  # nq>=2 preferred
    assert T.attn_block_q(4096, 128, backend="tpu") == 128
    assert T.attn_block_q(100, 16, backend="cpu") == 0  # nothing divides
    assert T.attn_block_q(4096, 64, backend="tpu") == 0  # lane misaligned
