"""The (m, n, backend) schedule autotuner (DESIGN.md §5).

``choose_kind`` picks which registered schedule kind a kernel should
launch for a given simplex dimension, tile count and backend — so
kernels and benchmarks never hand-pick a schedule (``kind='auto'``
everywhere, resolved through ``core.schedule.resolve_kind``).

Decision procedure:

1. **Candidates** — the kinds constructible at (m, n): the (w, h)-grid
   trio at m=2, the linear-grid kinds at m >= 3, each passed through
   ``resolve_kind`` (so 'hmap' at non-pow2 n competes as its actual
   'composite'/'rb' resolution) and deduplicated.
2. **Model scores** — ``roofline.analysis.schedule_cost_model``:
   memory-bound tile traffic (wasted steps pay full price) plus the
   per-step index-map overhead of each form (select chains, SMEM reads,
   amortized O(V) table builds).
3. **Measured ranking** — when ``compiled: true`` rows recorded in
   ``BENCH_maps.json`` (ACCUM tests, matching m/kind,
   backend-compatible, rescaled to this n by the steps ratio) cover
   *every* candidate kind, the decision ranks on them instead of the
   model; partial coverage keeps the model ranking (mixing measured
   wall-clocks with model estimates would penalize whichever kind
   happened to get benchmarked).  Provenance lands in
   ``Decision.source``.
4. **Disk cache** — decisions persist in a JSON cache keyed
   ``m,n,backend``; an entry is invalidated when the JAX version or the
   bench artifact fingerprint (content hash) changes, so fresh
   measurements re-tune automatically.

Env knobs: ``REPRO_AUTOTUNE_CACHE`` (cache file path),
``REPRO_BENCH_ARTIFACT`` (bench rows to consume),
``REPRO_AUTOTUNE_DISABLE=1`` (skip cache reads AND writes — hermetic
test runs), ``REPRO_SPLIT_PIECES`` (force the per-piece launch split on
or off).
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional, Tuple

from repro.roofline.analysis import (
    LAUNCH_OVERHEAD_S,
    SELECT_S,
    schedule_cost_model,
)

__all__ = [
    "Decision",
    "AttnDecision",
    "choose_kind",
    "choose_attn_impl",
    "attn_block_q",
    "candidate_kinds",
    "should_split_pieces",
    "clear_cache",
    "cache_path",
    "bench_artifact_path",
    "CACHE_SCHEMA",
    "ATTN_INTERPRET_STEP_CAP",
]

CACHE_SCHEMA = "repro-autotune/v1"

_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_BENCH_ENV = "REPRO_BENCH_ARTIFACT"
_DISABLE_ENV = "REPRO_AUTOTUNE_DISABLE"
_SPLIT_ENV = "REPRO_SPLIT_PIECES"
_ATTN_CAP_ENV = "REPRO_ATTN_STEP_CAP"

# Interpret-mode flash grid-step budget: heads x grid steps above which
# the Pallas emulator (INTERPRET_STEP_S per step) would dominate and the
# decision falls back to the fused-XLA chunked path.  Irrelevant on
# compiled (TPU/GPU) backends.
ATTN_INTERPRET_STEP_CAP = 4096


@dataclass(frozen=True)
class Decision:
    """One autotuner decision record (also the on-disk cache row).

    Attributes:
        m: Simplex dimension.
        n: Tile count per side the decision applies to.
        backend: Backend the decision was made for ('cpu', 'tpu', ...).
        kind: Winning schedule kind (already ``resolve_kind``-concrete).
        source: Provenance — 'measured' (BENCH_maps.json row), 'model'
            (roofline estimate) or 'cache' (served from disk).
        score_us: Predicted/measured cost of the winner, microseconds.
        scores_us: Per-candidate scores, for inspection.
        jax_version: JAX version the decision was computed under.
        fingerprint: Bench-artifact content hash at decision time.
    """

    m: int
    n: int
    backend: str
    kind: str
    source: str
    score_us: float
    scores_us: Dict[str, float]
    jax_version: str
    fingerprint: str


def _jax_version() -> str:
    import jax

    return jax.__version__


def _backend(backend: Optional[str]) -> str:
    from repro.kernels.policy import backend_name

    return backend_name(backend)


def cache_path(path: Optional[str] = None) -> str:
    """Resolve the decision-cache file path (env-overridable).

    Args:
        path: Explicit path; wins over the env var and default.

    Returns:
        Absolute path of the JSON cache file.
    """
    p = path or os.environ.get(_CACHE_ENV) or os.path.join(
        os.path.expanduser("~"), ".cache", "repro-simplex", "autotune.json"
    )
    return os.path.abspath(p)


def bench_artifact_path(path: Optional[str] = None) -> str:
    """Resolve the bench-rows artifact path (env-overridable).

    Args:
        path: Explicit path; wins over the env var and default
            (``BENCH_maps.json`` in the working directory).

    Returns:
        Absolute path (the file may be absent — that's a valid state).
    """
    p = path or os.environ.get(_BENCH_ENV) or "BENCH_maps.json"
    return os.path.abspath(p)


def _fingerprint(path: str) -> str:
    if not os.path.isfile(path):
        return "absent"
    with open(path, "rb") as f:
        return hashlib.sha1(f.read()).hexdigest()


def _load_cache(path: str) -> Dict:
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError):
        return {"schema": CACHE_SCHEMA, "entries": {}}
    if data.get("schema") != CACHE_SCHEMA:
        return {"schema": CACHE_SCHEMA, "entries": {}}
    return data


def _store_cache(path: str, data: Dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(data, f, indent=1)
        os.replace(tmp, path)
    except OSError:
        try:
            os.unlink(tmp)
        except OSError:
            pass


def clear_cache(path: Optional[str] = None) -> None:
    """Delete the on-disk decision cache (tests, manual re-tune).

    Args:
        path: Cache file; defaults to ``cache_path()``.
    """
    p = cache_path(path)
    if os.path.isfile(p):
        os.unlink(p)


def candidate_kinds(m: int, n: int) -> Tuple[str, ...]:
    """Kinds that actually compete at (m, n), post-``resolve_kind``.

    m=2 restricts to the (w, h)-grid trio the 2D kernels launch; m >= 3
    uses the linear-grid kinds.  Each requested kind is resolved (e.g.
    'hmap' at non-pow2 n competes as 'composite') and duplicates drop.

    Args:
        m: Simplex dimension.
        n: Tile count per side.

    Returns:
        Ordered tuple of distinct concrete kinds.
    """
    from repro.core.schedule import registered_kinds, resolve_kind

    base = ("hmap", "rb", "bb") if m == 2 else (
        "hmap", "table", "composite", "bb"
    )
    avail = set(registered_kinds(m))
    out: List[str] = []
    for k in base:
        if k not in avail:
            continue
        r = resolve_kind(m, n, k)
        if r not in out:
            out.append(r)
    return tuple(out)


def _model_scores(m: int, n: int, kinds: Tuple[str, ...]) -> Dict[str, float]:
    """Roofline-model score (us) per candidate kind.

    The memory term is evaluated at the smallest tile a compiled kernel
    actually moves — one 8x128 VREG footprint (1024 elements) spread
    over m axes — so the per-step map overhead is weighed against
    realistic tile traffic, not toy tiles.
    """
    from repro.core.schedule import SimplexSchedule
    from repro.core.trapezoids import decompose_simplex

    from repro.kernels.policy import TPU_LANE, TPU_SUBLANE

    rho_model = max(2, round((TPU_SUBLANE * TPU_LANE) ** (1.0 / m)))
    scores = {}
    for kind in kinds:
        sched = SimplexSchedule(m, n, kind)
        pieces = len(decompose_simplex(m, n)) if kind == "composite" else 1
        s = schedule_cost_model(
            kind, sched.steps, m=m, n=n, useful=sched.useful,
            pieces=pieces, rho=rho_model,
        )
        scores[kind] = s * 1e6
    return scores


def _measured_scores(
    m: int, n: int, kinds: Tuple[str, ...], backend: str, bench_file: str
) -> Dict[str, float]:
    """Scores (us) from recorded ACCUM rows, rescaled by the steps ratio.

    Only ``compiled: true`` rows count — interpret-mode timings measure
    the Pallas emulator, not the machine the model estimates, and must
    not override it.
    """
    try:
        with open(bench_file) as f:
            artifact = json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}
    from repro.core.schedule import SimplexSchedule

    best: Dict[str, Tuple[float, float]] = {}  # kind -> (|n_row - n|, us)
    for row in artifact.get("rows", []):
        test = str(row.get("test") or "")
        if not test.startswith("ACCUM"):
            continue
        if row.get("m") != m or row.get("map") not in kinds:
            continue
        if not row.get("compiled"):
            continue
        row_backend = row.get("backend")
        if row_backend is not None and row_backend != backend:
            continue
        us = row.get("us_per_call")
        steps_row = row.get("grid_steps")
        if not us or not steps_row:
            continue
        kind = row["map"]
        here = SimplexSchedule(m, n, kind).steps
        scaled = float(us) * here / float(steps_row)
        dist = abs(float(steps_row) - here)
        if kind not in best or dist < best[kind][0]:
            best[kind] = (dist, scaled)
    return {k: v[1] for k, v in best.items()}


def choose_kind(
    m: int,
    n: int,
    backend: Optional[str] = None,
    *,
    bench_path: Optional[str] = None,
    cache_file: Optional[str] = None,
    refresh: bool = False,
) -> Decision:
    """Pick the schedule kind for (m, n, backend); cache on disk.

    Args:
        m: Simplex dimension (m >= 2).
        n: Tile count per side.
        backend: Backend name; None uses the active JAX backend.
        bench_path: Bench artifact override (else env/default).
        cache_file: Cache file override (else env/default).
        refresh: Recompute even on a fresh cache hit.

    Returns:
        The winning ``Decision`` (``.kind`` is what kernels launch).

    Example:
        >>> import os
        >>> _old = os.environ.get("REPRO_AUTOTUNE_DISABLE")
        >>> os.environ["REPRO_AUTOTUNE_DISABLE"] = "1"  # hermetic
        >>> d = choose_kind(3, 8, backend="cpu")
        >>> d.kind in candidate_kinds(3, 8) and d.source != "cache"
        True
        >>> _ = (os.environ.pop("REPRO_AUTOTUNE_DISABLE") if _old is None
        ...      else os.environ.update(REPRO_AUTOTUNE_DISABLE=_old))
    """
    backend = _backend(backend)
    disabled = os.environ.get(_DISABLE_ENV, "").strip() == "1"
    bench_file = bench_artifact_path(bench_path)
    cpath = cache_path(cache_file)
    key = f"m={m},n={n},backend={backend}"
    fp = _fingerprint(bench_file)
    jv = _jax_version()

    if not disabled and not refresh:
        entry = _load_cache(cpath)["entries"].get(key)
        if (
            entry is not None
            and entry.get("jax_version") == jv
            and entry.get("fingerprint") == fp
        ):
            return Decision(
                m=m, n=n, backend=backend, kind=entry["kind"],
                source="cache", score_us=entry["score_us"],
                scores_us=entry.get("scores_us", {}),
                jax_version=jv, fingerprint=fp,
            )

    kinds = candidate_kinds(m, n)
    scores = _model_scores(m, n, kinds)
    measured = _measured_scores(m, n, kinds, backend, bench_file)
    # Rank on measured times only when EVERY candidate has one —
    # measured wall-clocks (whole-executor) and model estimates
    # (schedule overhead) are different units, and overriding a single
    # kind would penalize whichever kind happened to get benchmarked.
    use_measured = set(kinds) <= set(measured)
    merged = dict(measured) if use_measured else scores
    winner = min(merged, key=merged.get)
    decision = Decision(
        m=m, n=n, backend=backend, kind=winner,
        source="measured" if use_measured else "model",
        score_us=merged[winner], scores_us=merged,
        jax_version=jv, fingerprint=fp,
    )
    if not disabled:
        cache = _load_cache(cpath)
        row = asdict(decision)
        del row["m"], row["n"], row["backend"]
        cache["entries"][key] = row
        _store_cache(cpath, cache)
    return decision


# ---------------------------------------------------------------------------
# Attention-impl decisions (the serving hot path — DESIGN.md §8)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttnDecision:
    """One causal-attention dispatch decision (and its cache row).

    Attributes:
        seq: Sequence length the decision applies to.
        heads: Query-head count (per example).
        head_dim: Attention head dimension D.
        backend: Backend the decision was made for.
        impl: Winning executor — ``'flash'`` (simplex-grid Pallas
            kernel) or ``'chunked'`` (fused-XLA fallback).
        kind: Schedule of the winner: ``'folded'`` / ``'bb'`` for
            flash, ``'chunked'`` for the XLA path.
        block_q: Square tile side the flash kernel should launch with
            (also the chunk for the XLA path); 0 when no tile divides
            the sequence.
        source: Provenance — 'measured', 'model', 'cache' or
            'fallback' (shape unmappable by the flash kernel).
        score_us: Predicted/measured cost of the winner, microseconds.
        scores_us: Per-candidate scores, for inspection.
        jax_version: JAX version at decision time.
        fingerprint: Bench-artifact content hash at decision time.
    """

    seq: int
    heads: int
    head_dim: int
    backend: str
    impl: str
    kind: str
    block_q: int
    source: str
    score_us: float
    scores_us: Dict[str, float]
    jax_version: str
    fingerprint: str


_ATTN_BLOCKS = (128, 64, 32, 16, 8)


def attn_block_q(seq: int, head_dim: int, backend: Optional[str] = None) -> int:
    """Square attention tile side for a sequence length (0 if none fits).

    Compiled backends take the largest MXU-friendly divisor of ``seq``
    (biggest tile wins on real hardware).  Interpret backends prefer
    the largest divisor that still yields at least two query tiles, so
    the folded simplex walk is actually exercised rather than
    degenerating to the single-tile bounding box.

    Args:
        seq: Sequence length.
        head_dim: Attention head dim (alignment on the compiled path).
        backend: Backend name; None uses the active JAX backend.

    Returns:
        The chosen tile side, or 0 when no candidate divides ``seq``
        (the dispatch then falls back to the chunked XLA path).

    Example:
        >>> attn_block_q(64, 16, backend="cpu")   # interpret: nq=2 fold
        32
        >>> attn_block_q(4096, 128, backend="tpu")
        128
    """
    from repro.kernels.policy import TPU_LANE, TPU_SUBLANE, default_interpret

    interpret = default_interpret(backend)
    divisors = [bq for bq in _ATTN_BLOCKS if bq <= seq and seq % bq == 0]
    if not interpret:
        divisors = [
            bq for bq in divisors
            if bq % TPU_SUBLANE == 0 and head_dim % TPU_LANE == 0
        ]
    if not divisors:
        return 0
    if interpret:
        for bq in divisors:
            if seq // bq >= 2:
                return bq
    return divisors[0]


def _attn_model_scores(
    nq: int, heads: int, head_dim: int, block_q: int
) -> Dict[str, float]:
    """Analytic prior (us) per attention executor at device constants.

    Uses the roofline attention entries (``schedule_cost_model`` with
    ``attn-*`` kinds): the fold halves block-pair visits vs the
    bounding box, and the chunked XLA path pays the score-tile HBM
    round-trip flash keeps in VMEM.
    """
    from repro.kernels.flash_attention import flash_grid_steps

    tri = nq * (nq + 1) // 2
    scores = {}
    for kind in ("folded", "bb", "chunked"):
        steps = heads * flash_grid_steps(
            nq, "bb" if kind == "bb" else "folded"
        )
        scores[kind] = schedule_cost_model(
            f"attn-{kind}", steps, m=2, n=nq, useful=heads * tri,
            rho=block_q, head_dim=head_dim,
        ) * 1e6
    return scores


def _measured_attn_scores(
    nq: int, heads: int, kinds: Tuple[str, ...], backend: str, bench_file: str
) -> Dict[str, float]:
    """Scores (us) from recorded ATTN rows, rescaled by the steps ratio.

    Mirrors ``_measured_scores``: only ``compiled: true`` rows count
    (interpret-mode wall-clocks measure the emulator, not the machine).
    """
    try:
        with open(bench_file) as f:
            artifact = json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}
    from repro.kernels.flash_attention import flash_grid_steps

    best: Dict[str, Tuple[float, float]] = {}
    for row in artifact.get("rows", []):
        if row.get("test") != "ATTN" or row.get("map") not in kinds:
            continue
        if not row.get("compiled"):
            continue
        row_backend = row.get("backend")
        if row_backend is not None and row_backend != backend:
            continue
        us = row.get("us_per_call")
        steps_row = row.get("grid_steps")
        if not us or not steps_row:
            continue
        kind = row["map"]
        here = heads * flash_grid_steps(nq, "bb" if kind == "bb" else "folded")
        scaled = float(us) * here / float(steps_row)
        dist = abs(float(steps_row) - here)
        if kind not in best or dist < best[kind][0]:
            best[kind] = (dist, scaled)
    return {k: v[1] for k, v in best.items()}


def choose_attn_impl(
    seq: int,
    heads: int,
    head_dim: int,
    backend: Optional[str] = None,
    *,
    bench_path: Optional[str] = None,
    cache_file: Optional[str] = None,
    refresh: bool = False,
) -> AttnDecision:
    """Pick the causal-attention executor for (seq, heads, head_dim).

    The dispatch decision ``models.attention.simplex_attention`` (and
    ``ops.causal_flash_attention`` with ``kind='auto'``) resolves
    through — cached on disk next to the schedule decisions.  Ranking:
    measured ``compiled: true`` ATTN rows from ``BENCH_maps.json`` when
    they cover every candidate, else the roofline attention prior
    (``schedule_cost_model`` ``attn-*`` entries).  Two structural
    guards override the ranking:

    * no candidate tile divides ``seq`` (or the compiled path's 8x128
      alignment fails) — the flash kernel cannot map the shape, so the
      chunked XLA path wins as ``source='fallback'``;
    * on interpret backends, ``heads x grid_steps`` beyond
      ``ATTN_INTERPRET_STEP_CAP`` (env ``REPRO_ATTN_STEP_CAP``) — the
      Pallas emulator pays ``INTERPRET_STEP_S`` per step, so huge
      grids go to the chunked path; production (TPU/GPU) ignores the
      cap.

    Args:
        seq: Sequence length (static under jit — decisions happen at
            trace time).
        heads: Query-head count per example.
        head_dim: Attention head dimension.
        backend: Backend name; None uses the active JAX backend.
        bench_path: Bench artifact override (else env/default).
        cache_file: Cache file override (else env/default).
        refresh: Recompute even on a fresh cache hit.

    Returns:
        The winning ``AttnDecision`` (``.impl``/``.kind``/``.block_q``
        are what the dispatch launches).

    Example:
        >>> import os
        >>> _old = os.environ.get("REPRO_AUTOTUNE_DISABLE")
        >>> os.environ["REPRO_AUTOTUNE_DISABLE"] = "1"  # hermetic
        >>> d = choose_attn_impl(64, 4, 16, backend="cpu")
        >>> (d.impl, d.kind, 64 % d.block_q)
        ('flash', 'folded', 0)
        >>> _ = (os.environ.pop("REPRO_AUTOTUNE_DISABLE") if _old is None
        ...      else os.environ.update(REPRO_AUTOTUNE_DISABLE=_old))
    """
    from repro.kernels.flash_attention import flash_grid_steps
    from repro.kernels.policy import default_interpret

    backend = _backend(backend)
    disabled = os.environ.get(_DISABLE_ENV, "").strip() == "1"
    bench_file = bench_artifact_path(bench_path)
    cpath = cache_path(cache_file)
    key = f"attn,s={seq},h={heads},d={head_dim},backend={backend}"
    fp = _fingerprint(bench_file)
    jv = _jax_version()

    if not disabled and not refresh:
        entry = _load_cache(cpath)["entries"].get(key)
        if (
            entry is not None
            and entry.get("jax_version") == jv
            and entry.get("fingerprint") == fp
        ):
            return AttnDecision(
                seq=seq, heads=heads, head_dim=head_dim, backend=backend,
                impl=entry["impl"], kind=entry["kind"],
                block_q=entry["block_q"], source="cache",
                score_us=entry["score_us"],
                scores_us=entry.get("scores_us", {}),
                jax_version=jv, fingerprint=fp,
            )

    interpret = default_interpret(backend)
    block = attn_block_q(seq, head_dim, backend)
    nq = seq // block if block else 0
    flash_ok = block > 0
    if flash_ok and interpret:
        cap = int(os.environ.get(_ATTN_CAP_ENV, "") or ATTN_INTERPRET_STEP_CAP)
        flash_ok = heads * flash_grid_steps(nq, "folded") <= cap

    if not flash_ok:
        decision = AttnDecision(
            seq=seq, heads=heads, head_dim=head_dim, backend=backend,
            impl="chunked", kind="chunked", block_q=block,
            source="fallback", score_us=0.0, scores_us={},
            jax_version=jv, fingerprint=fp,
        )
    else:
        kinds = ("folded", "bb", "chunked")
        scores = _attn_model_scores(nq, heads, head_dim, block)
        measured = _measured_attn_scores(nq, heads, kinds, backend, bench_file)
        use_measured = set(kinds) <= set(measured)
        merged = dict(measured) if use_measured else scores
        winner = min(merged, key=merged.get)
        decision = AttnDecision(
            seq=seq, heads=heads, head_dim=head_dim, backend=backend,
            impl="chunked" if winner == "chunked" else "flash",
            kind=winner, block_q=block,
            source="measured" if use_measured else "model",
            score_us=merged[winner], scores_us=merged,
            jax_version=jv, fingerprint=fp,
        )
    if not disabled:
        cache = _load_cache(cpath)
        row = asdict(decision)
        for drop in ("seq", "heads", "head_dim", "backend"):
            del row[drop]
        cache["entries"][key] = row
        _store_cache(cpath, cache)
    return decision


def should_split_pieces(n_pieces: int, steps: int) -> bool:
    """Split a composite schedule into per-piece launches?

    The branchless composite map pays an O(pieces) select chain on
    every grid step; splitting removes the chain at the cost of one
    extra launch per piece.  Per extra launch the saving is
    ``steps * SELECT_S`` (each remaining launch drops ~one chain
    element per step), so split when that exceeds
    ``LAUNCH_OVERHEAD_S`` — and only when there are enough pieces for
    the chain to matter.  ``REPRO_SPLIT_PIECES=1/0`` forces it.

    Args:
        n_pieces: Piece count of the decomposition.
        steps: Total grid steps of the unsplit schedule.

    Returns:
        True when per-piece launches are predicted to win.

    Example:
        >>> should_split_pieces(2, 10**6), should_split_pieces(30, 10**6)
        (False, True)
    """
    env = os.environ.get(_SPLIT_ENV, "").strip()
    if env == "1":
        return True
    if env == "0":
        return False
    if n_pieces < 4:
        return False
    return steps * SELECT_S > LAUNCH_OVERHEAD_S
