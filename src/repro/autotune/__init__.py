"""Cached (m, n, backend) schedule autotuner — see ``tuner`` module."""

from .tuner import (
    CACHE_SCHEMA,
    Decision,
    bench_artifact_path,
    cache_path,
    candidate_kinds,
    choose_kind,
    clear_cache,
    should_split_pieces,
)

__all__ = [
    "CACHE_SCHEMA",
    "Decision",
    "bench_artifact_path",
    "cache_path",
    "candidate_kinds",
    "choose_kind",
    "clear_cache",
    "should_split_pieces",
]
