"""Cached (m, n, backend) schedule autotuner — see ``tuner`` module."""

from .tuner import (
    ATTN_INTERPRET_STEP_CAP,
    CACHE_SCHEMA,
    AttnDecision,
    Decision,
    attn_block_q,
    bench_artifact_path,
    cache_path,
    candidate_kinds,
    choose_attn_impl,
    choose_kind,
    clear_cache,
    should_split_pieces,
)

__all__ = [
    "ATTN_INTERPRET_STEP_CAP",
    "CACHE_SCHEMA",
    "AttnDecision",
    "Decision",
    "attn_block_q",
    "bench_artifact_path",
    "cache_path",
    "candidate_kinds",
    "choose_attn_impl",
    "choose_kind",
    "clear_cache",
    "should_split_pieces",
]
