"""State-of-the-art comparison maps from the paper (§3, §5, Fig. 9).

* BB  — bounding box, f(x) = x with a discard predicate (Eq. 2).
* RB  — rectangular box [37] (Jung & O'Leary): fold the lower triangle
        into an (n+1)/2 x n rectangle.
* LAMBDA — the enumeration map lambda(omega) [22, 24] (Navarro et al.):
        recovers 2D/3D coordinates of the i-th simplex element from the
        closed-form inversion of the simplicial number — requires square
        (2-simplex) or cube (3-simplex) roots; FP precision limits the
        valid range exactly as the paper describes (§3: n <= 62900 for
        FP32 2-simplex / n <= 1546 for 3-simplex before FP64 is needed).
* DP  — CUDA dynamic parallelism has **no TPU analogue** (no device-side
        grid launch); documented in DESIGN.md, not implemented.

All maps are dual-backend (numpy / jax tracers) like ``hmap``.
"""

from __future__ import annotations

from typing import Any, Tuple

import numpy as np

__all__ = [
    "bb_map2",
    "bb_valid2",
    "bb_map3",
    "bb_valid3",
    "rb_map2",
    "rb_grid_shape",
    "lambda_map2",
    "lambda_map3",
    "lambda_fp32_exact_range_2d",
]


def _xp(*xs: Any):
    for x in xs:
        if type(x).__module__.startswith("jax"):
            import jax.numpy as jnp

            return jnp
    return np


# --------------------------------------------------------------------------
# Bounding box
# --------------------------------------------------------------------------


def bb_map2(wx, wy) -> Tuple[Any, Any]:
    """Identity map (Eq. 2); used with ``bb_valid2`` as run-time filter."""
    return wx, wy


def bb_valid2(x, y):
    """Inclusive lower-triangle predicate {x <= y} discarding ~n^2/2 blocks."""
    return x <= y


def bb_map3(wx, wy, wz) -> Tuple[Any, Any, Any]:
    """Identity bounding-box map for the 3-simplex (pair with bb_valid3)."""
    return wx, wy, wz


def bb_valid3(x, y, z, n: int):
    """T(n) predicate; discards ~5/6 of the n^3 bounding box."""
    return (x + y + z) < n


# --------------------------------------------------------------------------
# Rectangular box (RB) [37]
# --------------------------------------------------------------------------


def rb_grid_shape(n: int) -> Tuple[int, int]:
    """Grid (width, height) covering the inclusive lower triangle of n x n.

    n even: (n/2, n+1) — same zero-waste volume as hmap2_full.
    """
    assert n % 2 == 0, "RB fold here assumes even n (block counts are even)"
    return n // 2, n + 1


def rb_map2(wx, wy, n: int) -> Tuple[Any, Any]:
    """RB fold over grid (n/2, n+1), wy in [0, n]:

        wy >  wx:  (x, y) = (wx, wy - 1)                [direct left half]
        wy <= wx:  (x, y) = (n/2 + wy, n/2 + wx)        [folded right half]

    The missing right-half tiles {x >= n/2, x <= y} form an inclusive
    upper triangle of side n/2 — exactly the fold region {wy <= wx}.
    Bijective onto {x <= y <= n-1} (verified in tests).  One comparison +
    adds: O(1), exact, but 2-simplex only — the paper discards RB for
    3-simplices (§5.3).
    """
    xp = _xp(wx, wy)
    fold = wy <= wx
    x = xp.where(fold, n // 2 + wy, wx)
    y = xp.where(fold, n // 2 + wx, wy - 1)
    return x, y


# --------------------------------------------------------------------------
# Lambda enumeration map [22, 24]
# --------------------------------------------------------------------------


def lambda_map2(w, dtype=np.float32) -> Tuple[Any, Any]:
    """lambda(w): Z -> Z^2 via the triangular-number inversion.

    Element w (0-based) of the inclusive lower triangle maps to
        y = floor( (sqrt(8w + 1) - 1) / 2 ),   x = w - y(y+1)/2.
    The square root is computed in ``dtype`` — FP32 reproduces the paper's
    precision failure beyond n ~ 62900 (the TITAN RTX discussion, §5.2).
    """
    xp = _xp(w)
    wf = xp.asarray(w).astype(dtype)
    y = xp.floor((xp.sqrt(dtype(8.0) * wf + dtype(1.0)) - dtype(1.0)) / dtype(2.0))
    y = y.astype(xp.int64 if xp is np else xp.asarray(w).dtype)
    # one Newton correction step in integer space guards the FP boundary
    # (the paper's maps apply the analogous epsilon correction)
    tri_y = y * (y + 1) // 2
    y = xp.where(tri_y > xp.asarray(w), y - 1, y)
    tri_y = y * (y + 1) // 2
    over = xp.asarray(w) - tri_y > y
    y = xp.where(over, y + 1, y)
    tri_y = y * (y + 1) // 2
    x = xp.asarray(w) - tri_y
    return x, y


def lambda_map2_raw(w, dtype=np.float32) -> Tuple[Any, Any]:
    """Uncorrected lambda map — exhibits the raw FP32 failure range."""
    xp = _xp(w)
    wf = xp.asarray(w).astype(dtype)
    y = xp.floor((xp.sqrt(dtype(8.0) * wf + dtype(1.0)) - dtype(1.0)) / dtype(2.0))
    y = y.astype(np.int64) if xp is np else y.astype("int32")
    x = xp.asarray(w) - y * (y + 1) // 2
    return x, y


def lambda_fp32_exact_range_2d() -> int:
    """Largest n for which the *uncorrected* FP32 lambda map is exact.

    Computed by direct scan (used by a test to reproduce the paper's
    'map is accurate only in a bounded range' claim qualitatively).
    """
    n = 1
    step = 4096
    while True:
        w = np.arange(tri_total(n + step) - 10, tri_total(n + step), dtype=np.int64)
        x, y = lambda_map2_raw(w)
        ok = np.all((x >= 0) & (x <= y))
        if not ok:
            return n
        n += step
        if n > (1 << 20):
            return n


def tri_total(n: int) -> int:
    """Triangular number n(n+1)/2 — the lambda maps' linear-domain size."""
    return n * (n + 1) // 2


def lambda_map3(w, dtype=np.float64) -> Tuple[Any, Any, Any]:
    """lambda_3(w): Z -> Z^3 via tetrahedral-number inversion (cube root).

    Solves z from w = z(z+1)(z+2)/6 using the real root of the cubic
    (paper [23, 24]); requires cbrt — the numerically fragile part the
    paper's H map eliminates.  Integer-corrected like lambda_map2.
    NOTE [24] maps onto the *order* simplex i<j<k; here we compose with
    the prefix-difference bijection to land on the standard simplex.
    """
    xp = _xp(w)
    wf = xp.asarray(w).astype(dtype)
    # invert v = z(z+1)(z+2)/6 ~ (z+1)^3/6  =>  z ~ cbrt(6v) - 1
    z = xp.floor(xp.cbrt(dtype(6.0) * wf + dtype(1.0)) - dtype(1.0))
    z = z.astype(np.int64) if xp is np else z.astype("int32")
    tet_z = z * (z + 1) * (z + 2) // 6
    z = xp.where(tet_z > xp.asarray(w), z - 1, z)
    tet_z = z * (z + 1) * (z + 2) // 6
    over = xp.asarray(w) - tet_z >= (z + 1) * (z + 2) // 2
    z = xp.where(over, z + 1, z)
    tet_z = z * (z + 1) * (z + 2) // 6
    rem = xp.asarray(w) - tet_z
    x2, y2 = lambda_map2(rem, dtype=np.float32 if dtype == np.float32 else np.float64)
    # (x2 <= y2 <= z) is the order simplex; prefix-difference to standard:
    return x2, y2 - x2, z - y2
