"""Decompositions of general-n simplex domains into power-of-two pieces.

The paper's map H requires a power-of-two n (§4.1) and handles general n
by decomposing the domain into a small set of exactly-schedulable pieces
(§4.2).  This module implements both generations of that idea:

* **2-simplex trapezoids** (the paper's concurrent-kernel scheme):
  power-of-two triangles along the diagonal, each completed by the
  rectangular box to its left.  ``decompose`` / ``trapezoid_map`` keep
  the per-piece (w, h) grids of the original scheme — one concurrent
  launch per piece.

* **General-m composite decomposition** (ours, DESIGN.md §4.2): for any
  dimension m >= 2 and any side n, the strict simplex
  ``T^m(n) = {x >= 0, sum(x) < n}`` splits exactly as

      T^m(n) = T^m(p)  ⊎  Shell^m(p, n),        p = pow2_floor(n)
      Shell^m(p, n) = ⊎_{k=0}^{m-1}  T^k(p) ⋉ T^{m-k}(q),   q = n - p

  where ``T^k(p) ⋉ T^{m-k}(q)`` is a *sheared prism*: a power-of-two
  k-simplex prefix over the top k coordinates whose sum ``s`` shears the
  remainder simplex's top coordinate by ``p - s``.  Every prefix is
  power-of-two (served by ``hmap_factor``); every remainder ``T^{m-k}(q)``
  recurses on the strictly smaller, generally non-power-of-two q.
  Flattening the recursion yields *atomic pieces* — chains of
  power-of-two factors — concatenated into one linear grid.  The piece
  count is O(log^m n): at most C(log2(n) + m, m), measured e.g. 30 at
  (m=4, n=23) and 2870 at (m=4, n=2^20-1).  Host-side construction is
  O(pieces), never O(V); note the branchless map also decodes every
  piece per evaluated index, so per-step map cost grows with the piece
  count (the table kind pays one SMEM read instead — see DESIGN.md §4.2
  for when each wins).

All piece maps are branchless and dual-backend (numpy or jax tracers),
so a composite schedule drops straight into a Pallas ``index_map`` or a
host-side oracle, exactly like the power-of-two maps in ``core/hmap.py``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Tuple

import numpy as np

from .hmap import _is_jax, hmap2_full, hmap_factor, hmap_factor_grid_size

__all__ = [
    "Trapezoid",
    "decompose",
    "trapezoid_map",
    "total_grid_cells",
    "SimplexPiece",
    "decompose_simplex",
    "composite_grid_size",
    "composite_map",
    "piece_map",
]


@dataclass(frozen=True)
class Trapezoid:
    """One piece of the 2-simplex concurrent-trapezoid decomposition.

    A trapezoid covers data rows ``[offset, offset + side)`` of the
    inclusive lower triangle: the power-of-two triangle of side ``side``
    on the diagonal plus the ``side x offset`` box completing its rows to
    the left.

    Attributes:
        offset: First data row covered; also the width of the box part.
        side: Triangle side length (a power of two).
        overshoot: Rows beyond n covered by a rounded-up final piece
            (``trapezoid_map`` flags them invalid at run time).

    Example:
        >>> t = Trapezoid(offset=4, side=2, overshoot=0)
        >>> t.grid_shape, t.grid_cells, t.data_tiles
        ((1, 11), 11, 11)
    """

    offset: int  # o_i: first data row / box width
    side: int  # s_i: triangle side (power of two)
    overshoot: int  # rows beyond n covered by the final rounded-up piece

    @property
    def grid_shape(self) -> Tuple[int, int]:
        """(width, height) of this piece's grid: width s/2, height (s+1) + 2*o.

        A side-1 trapezoid (odd-n tail) is a single data row of
        offset+1 tiles: grid (1, offset+1).
        """
        if self.side == 1:
            return 1, self.offset + 1
        return self.side // 2, (self.side + 1) + 2 * self.offset

    @property
    def grid_cells(self) -> int:
        """Total grid cells launched for this piece (width * height)."""
        w, h = self.grid_shape
        return w * h

    @property
    def data_tiles(self) -> int:
        """Tiles actually inside the simplex (excludes overshoot rows)."""
        s, o = self.side, self.offset
        full = o * s + s * (s + 1) // 2
        if self.overshoot:
            # rows beyond n: the overshoot rows are the LAST ones
            for y in range(s - self.overshoot, s):
                full -= o + y + 1
        return full


def decompose(n: int, threshold: int = 4) -> List[Trapezoid]:
    """Split the side-n lower triangle into concurrent trapezoids.

    Paper §4.2 option 3: approach n from below with power-of-two
    triangle pieces; once the remainder drops under ``threshold`` it is
    rounded *up* to the next power of two (one final trapezoid whose
    excess rows are filtered at run time).  Worst case log2(n) pieces,
    typically ~2-4.

    Args:
        n: Side of the triangle domain (rows), n >= 1.
        threshold: Remainder size below which the tail is rounded up
            instead of split further.

    Returns:
        List of ``Trapezoid`` pieces covering rows [0, n) exactly.

    Example:
        >>> [(t.offset, t.side, t.overshoot) for t in decompose(6)]
        [(0, 4, 0), (4, 2, 0)]
        >>> [(t.offset, t.side, t.overshoot) for t in decompose(7)]
        [(0, 4, 0), (4, 4, 1)]
    """
    assert n >= 1
    pieces: List[Trapezoid] = []
    offset = 0
    remaining = n
    while remaining > 0:
        p = 1 << (remaining.bit_length() - 1)  # largest power of two <= rem
        if remaining < threshold and (1 << remaining.bit_length()) // 2 != remaining:
            # round the tail up: one final trapezoid with overshoot
            p_up = 1 << remaining.bit_length()
            pieces.append(Trapezoid(offset, p_up, p_up - remaining))
            return pieces
        pieces.append(Trapezoid(offset, p, 0))
        offset += p
        remaining -= p
    return pieces


def trapezoid_map(t: Trapezoid, wx, wy) -> Tuple[Any, Any, Any]:
    """Map grid coordinates of one trapezoid to global data tiles.

    Grid rows [0, side] walk the power-of-two triangle through
    ``hmap2_full`` (zero waste); rows above realize Eq. 19's box fold —
    two grid rows per side/2-wide strip, fold mask ``k = (h1 - wy) >> 31``
    used as a 0/1 selector exactly as in the paper.  Dual-backend,
    branchless.

    Args:
        t: The trapezoid piece (from ``decompose``).
        wx: Grid column index/array, in [0, grid_shape[0]).
        wy: Grid row index/array, in [0, grid_shape[1]).

    Returns:
        ``(x, y, valid)`` global tile coordinates; ``valid`` is 0 only on
        overshoot rows of a rounded-up final trapezoid.

    Example:
        >>> t = Trapezoid(offset=4, side=2, overshoot=0)
        >>> x, y, v = trapezoid_map(t, np.zeros(11, np.int64), np.arange(11))
        >>> sorted(zip(y.tolist(), x.tolist()))[:5]
        [(4, 0), (4, 1), (4, 2), (4, 3), (4, 4)]
    """
    s, o = t.side, t.offset
    h1 = s  # last triangle grid row index (rows 0..s are triangle)
    if type(wx).__module__.startswith("jax") or type(wy).__module__.startswith("jax"):
        import jax.numpy as jnp

        xp = jnp
    else:
        xp = np
        wx, wy = np.asarray(wx), np.asarray(wy)
    if s == 1:  # single data row: tile (wy, offset)
        ones = xp.ones_like(wx, dtype=bool)
        return wy, o + xp.zeros_like(wy), ones
    # fold mask: k = (h1 - wy) >> 31 interpreted as 0/1 (paper Eq. 19)
    k = ((h1 - wy) >> 31) & 1
    # triangle part (k == 0)
    tx, ty = hmap2_full(wx, xp.minimum(wy, h1), s)
    # box part (k == 1): linear cell l = (wy - (s+1)) * s/2 + wx over o*s box
    l = (wy - (s + 1)) * (s // 2) + wx
    bx = l % xp.maximum(o, 1)
    by = l // xp.maximum(o, 1)
    x = xp.where(k == 1, bx, o + tx)
    y_local = xp.where(k == 1, by, ty)
    y = o + y_local
    valid = y_local < (s - t.overshoot)
    return x, y, valid


def total_grid_cells(n: int, threshold: int = 4) -> int:
    """Total grid cells across all trapezoids of ``decompose(n, threshold)``.

    Args:
        n: Side of the triangle domain.
        threshold: Passed through to ``decompose``.

    Returns:
        Sum of per-piece grid cells — the scheme's total parallel space.

    Example:
        >>> total_grid_cells(6)  # tri(6) = 21: zero waste at even n
        21
    """
    return sum(t.grid_cells for t in decompose(n, threshold))


# ---------------------------------------------------------------------------
# General-m composite decomposition (DESIGN.md §4.2)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SimplexPiece:
    """One atomic piece of the general-m composite decomposition.

    A piece is a chain of simplex *factors* ``(dim, side, delta)``
    occupying coordinate groups from the top coordinate ``x_{m-1}``
    downward.  All factors except possibly the last have power-of-two
    side (decoded by ``hmap_factor``); the last factor may be an
    interval (dim 1) of any side.  ``delta`` is the static shear offset
    added to the factor's top coordinate (accumulated from P_0 branches
    of the recursion); the dynamic shear ``side - sum(z)`` of each
    factor is applied to the next factor's top coordinate at decode time.

    Attributes:
        groups: Chain ``((dim, side, delta), ...)``; dims sum to the
            ambient m of the decomposition that produced the piece.

    Example:
        >>> piece = SimplexPiece(((1, 2, 0), (1, 1, 0)))
        >>> piece.grid_cells, piece.data_cells
        (2, 2)
    """

    groups: Tuple[Tuple[int, int, int], ...]

    @property
    def grid_cells(self) -> int:
        """Grid cells this piece launches: product of factor grid sizes."""
        g = 1
        for dim, side, _ in self.groups:
            g *= hmap_factor_grid_size(side, dim)
        return g

    @property
    def data_cells(self) -> int:
        """Simplex cells the piece covers: product of factor volumes."""
        import math

        v = 1
        for dim, side, _ in self.groups:
            v *= math.comb(side + dim - 1, dim)
        return v


def _is_pow2(s: int) -> bool:
    return s >= 1 and (s & (s - 1)) == 0


def decompose_simplex(m: int, n: int) -> List[SimplexPiece]:
    """Decompose the strict m-simplex T^m(n) into power-of-two pieces.

    The recursion (module docstring; DESIGN.md §4.2): with
    ``p = pow2_floor(n)`` and ``q = n - p``,

    * the **core** T^m(p) is one piece (power of two);
    * shell piece **P_0** is T^m(q) with its top coordinate sheared by a
      static +p — recurse on (m, q);
    * shell piece **P_k** (1 <= k < m) is a power-of-two k-simplex
      prefix T^k(p) over the top k coordinates, shearing a recursive
      T^{m-k}(q) remainder by ``p - sum(z)``.

    Terminal regions (dimension 1, or power-of-two side) become single
    factors.  The returned pieces partition T^m(n) exactly — verified
    exhaustively in ``tests/test_composite.py``.

    Args:
        m: Simplex dimension, m >= 1.
        n: Side length, n >= 1 (any value, not just powers of two).

    Returns:
        List of ``SimplexPiece``; total ``data_cells`` equals
        ``simplex_volume(n, m)``.  At most C(log2(n) + m, m) pieces
        (O(log^m n)), O(1) host work each.

    Example:
        >>> [p.groups for p in decompose_simplex(2, 3)]
        [((2, 2, 0),), ((2, 1, 2),), ((1, 2, 0), (1, 1, 0))]
        >>> sum(p.data_cells for p in decompose_simplex(3, 7))  # C(9,3)
        84
    """
    assert m >= 1 and n >= 1

    def _rec(d: int, s: int, delta: int) -> List[Tuple[Tuple[int, int, int], ...]]:
        if d == 1 or _is_pow2(s):
            return [((d, s, delta),)]
        p = 1 << (s.bit_length() - 1)
        q = s - p
        chains = [((d, p, delta),)]  # core
        chains += _rec(d, q, delta + p)  # P_0: static shear by p
        for k in range(1, d):
            for sub in _rec(d - k, q, 0):
                chains.append(((k, p, delta),) + sub)  # P_k prefix
        return chains

    return [SimplexPiece(c) for c in _rec(m, n, 0)]


def composite_grid_size(m: int, n: int) -> int:
    """Total linear-grid steps of the composite schedule for T^m(n).

    Pure O(pieces) arithmetic — reading the composite schedule's size
    never enumerates the simplex.

    Args:
        m: Simplex dimension.
        n: Side length (any n >= 1).

    Returns:
        Sum of per-piece grid cells; >= ``simplex_volume(n, m)``, with
        equality (zero waste) whenever every factor has dim <= 2.

    Example:
        >>> composite_grid_size(2, 100)  # m=2 composite is zero-waste
        5050
    """
    return sum(p.grid_cells for p in decompose_simplex(m, n))


def _decode_piece(piece: SimplexPiece, m: int, local, xp):
    """Decode one piece's local linear index to global strict coords."""
    sizes = [hmap_factor_grid_size(s, d) for d, s, _ in piece.groups]
    coords: List[Any] = [None] * m
    valid = None
    dyn = xp.zeros_like(local)
    hi = m - 1
    rem = local
    for g, (dim, side, delta) in enumerate(piece.groups):
        stride = 1
        for sz in sizes[g + 1 :]:
            stride *= sz
        idx_g = rem // stride
        rem = rem - idx_g * stride
        out = hmap_factor(idx_g, side, dim)
        cs, vg = out[:-1], out[-1]
        valid = vg if valid is None else (valid & vg)
        sumz = cs[0]
        for c in cs[1:]:
            sumz = sumz + c
        shift = dyn + delta
        # factor slot dim-1 is the group's top coordinate: it takes the
        # shear; lower slots map to the next coordinate indices down.
        for j in range(dim):
            coords[hi - (dim - 1) + j] = cs[j] + (shift if j == dim - 1 else 0)
        dyn = side - sumz
        hi -= dim
    return coords, valid


def piece_map(piece: SimplexPiece, m: int, lin):
    """Decode ONE piece's local grid index — no O(pieces) select chain.

    The composite ``composite_map`` decodes every piece per evaluated
    index (branchless selects); when a schedule is *split* into one
    launch per piece (``SimplexSchedule.split_pieces``), each launch
    decodes only its own factor chain — O(factors) work per step
    regardless of how many pieces the decomposition produced.

    Args:
        piece: One piece from ``decompose_simplex(m, n)``.
        m: Simplex dimension (sum of the piece's group dims).
        lin: Local linear index/array in ``[0, piece.grid_cells)``.

    Returns:
        ``(x_0, ..., x_{m-1}, valid)`` with the same conventions as
        ``composite_map`` (invalid steps pinned to the origin).

    Example:
        >>> ps = decompose_simplex(2, 3)
        >>> xs, ys, v = piece_map(ps[0], 2, np.arange(ps[0].grid_cells))
        >>> sorted(zip(xs[v].tolist(), ys[v].tolist()))
        [(0, 0), (0, 1), (1, 0)]
    """
    if _is_jax(lin):
        import jax.numpy as jnp

        xp = jnp
        lin = jnp.asarray(lin)
    else:
        xp = np
        lin = np.asarray(lin, dtype=np.int64)
    cs, v = _decode_piece(piece, m, lin, xp)
    cs = [xp.where(v, c, 0) for c in cs]
    return tuple(cs) + (v,)


def composite_map(pieces: List[SimplexPiece], m: int, lin):
    """Map a composite schedule's linear grid index to simplex coords.

    Pieces are concatenated in order; the index selects its piece by
    comparison against static prefix offsets (branchless, like the level
    decode of ``hmap_m_recursive``) and decodes the piece's factor chain.
    Dual-backend: numpy arrays host-side, jax tracers inside Pallas
    ``index_map``s.

    Args:
        pieces: Pieces from ``decompose_simplex(m, n)``.
        m: Simplex dimension (sum of group dims of every piece).
        lin: Linear grid index/array in ``[0, composite_grid_size(m, n))``.

    Returns:
        ``(x_0, ..., x_{m-1}, valid)`` in math order (strict simplex
        convention ``sum(x) < n``); invalid steps are the dead cells of
        dim >= 3 power-of-two factors and report coordinates pinned to
        0 — like every other kind, coordinates stay in [0, n) even when
        invalid, so kernels may feed them to a BlockSpec unconditionally
        (a raw dead-cell shear would go negative).

    Example:
        >>> ps = decompose_simplex(2, 3)
        >>> xs, ys, v = composite_map(ps, 2, np.arange(6))
        >>> sorted(zip(xs[v].tolist(), ys[v].tolist()))
        [(0, 0), (0, 1), (0, 2), (1, 0), (1, 1), (2, 0)]
    """
    if _is_jax(lin):
        import jax.numpy as jnp

        xp = jnp
        lin = jnp.asarray(lin)
    else:
        xp = np
        lin = np.asarray(lin, dtype=np.int64)
    out_coords = [xp.zeros_like(lin) for _ in range(m)]
    out_valid = xp.zeros_like(lin, dtype=bool)
    off = 0
    for piece in pieces:
        g = piece.grid_cells
        sel = (lin >= off) & (lin < off + g)
        local = xp.clip(lin - off, 0, g - 1)
        cs, v = _decode_piece(piece, m, local, xp)
        for j in range(m):
            out_coords[j] = xp.where(sel, cs[j], out_coords[j])
        out_valid = out_valid | (sel & v)
        off += g
    # dead cells of dim >= 3 factors can shear negative; pin invalid
    # steps to the origin so coordinates honour the [0, n) contract.
    out_coords = [xp.where(out_valid, c, 0) for c in out_coords]
    return tuple(out_coords) + (out_valid,)
