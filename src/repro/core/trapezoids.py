"""Concurrent-trapezoids extension of H to general n (paper §4.2, option 3).

For non-power-of-two n, the simplex is decomposed into a small set of
trapezoids: power-of-two triangles along the diagonal, each with the
rectangular "box" completing its rows to the left.  The set follows the
paper's rule — approach n from below with power-of-two pieces; the last
piece approaches from above when the remainder drops under the threshold
``T`` (limiting the set size; worst case log2 n pieces, typically ~2-4).

Each trapezoid gets its own *exact* grid (the paper's concurrent-kernel
launches; on TPU these become either separate ``pallas_call``s or one
fused grid — grid steps are cheap, there is no kernel-launch cost to
amortize, see DESIGN.md).  Geometry per trapezoid ``i``
(offset o_i, triangle side s_i, power of two):

  data rows   y in [o_i, o_i + s_i), global row y has y+1 tiles
  tiles       = box (s_i rows x o_i cols)  +  inclusive triangle side s_i
  grid        = (s_i/2, (s_i + 1) + 2*o_i/1)  rows:
                  rows [0, s_i]         -> hmap2_full triangle (zero waste)
                  rows (s_i, s_i+2*o_i] -> box fold, 2 rows of grid per
                                           s_i/2-wide strip (zero waste)

This realizes Eq. 19's B1/B2 box fold row-wise; the printed Eq. 19
constants are figure-dependent (see DESIGN.md §2) but the mechanism —
offset delta, fold mask k from a sign bit, grid-width translation — is
the same.  The fold mask below is literally ``k = (h1 - wy) >> 31`` used
as a 0/1 selector, as in the paper.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Tuple

import numpy as np

from .hmap import hmap2_full

__all__ = ["Trapezoid", "decompose", "trapezoid_map", "total_grid_cells"]


@dataclass(frozen=True)
class Trapezoid:
    offset: int  # o_i: first data row / box width
    side: int  # s_i: triangle side (power of two)
    overshoot: int  # rows beyond n covered by the final rounded-up piece

    @property
    def grid_shape(self) -> Tuple[int, int]:
        """(width, height): width s/2, height (s+1) + 2*offset.

        A side-1 trapezoid (odd-n tail) is a single data row of
        offset+1 tiles: grid (1, offset+1).
        """
        if self.side == 1:
            return 1, self.offset + 1
        return self.side // 2, (self.side + 1) + 2 * self.offset

    @property
    def grid_cells(self) -> int:
        w, h = self.grid_shape
        return w * h

    @property
    def data_tiles(self) -> int:
        """Tiles actually inside the simplex (excludes overshoot rows)."""
        s, o = self.side, self.offset
        full = o * s + s * (s + 1) // 2
        if self.overshoot:
            # rows beyond n: the overshoot rows are the LAST ones
            for y in range(s - self.overshoot, s):
                full -= o + y + 1
        return full


def decompose(n: int, threshold: int = 4) -> List[Trapezoid]:
    """Paper §4.2 option 3: power-of-two pieces from below; the final
    remainder is rounded *up* to the next power of two once it is smaller
    than ``threshold`` (its excess rows are filtered at run time)."""
    assert n >= 1
    pieces: List[Trapezoid] = []
    offset = 0
    remaining = n
    while remaining > 0:
        p = 1 << (remaining.bit_length() - 1)  # largest power of two <= rem
        if remaining < threshold and (1 << remaining.bit_length()) // 2 != remaining:
            # round the tail up: one final trapezoid with overshoot
            p_up = 1 << remaining.bit_length()
            pieces.append(Trapezoid(offset, p_up, p_up - remaining))
            return pieces
        pieces.append(Trapezoid(offset, p, 0))
        offset += p
        remaining -= p
    return pieces


def trapezoid_map(t: Trapezoid, wx, wy) -> Tuple[Any, Any, Any]:
    """Map grid (wx, wy) of trapezoid ``t`` to global data tile (x, y).

    Returns (x, y, valid).  valid=0 only on overshoot rows of a rounded-up
    final trapezoid.  Dual-backend, branchless.
    """
    s, o = t.side, t.offset
    h1 = s  # last triangle grid row index (rows 0..s are triangle)
    if type(wx).__module__.startswith("jax") or type(wy).__module__.startswith("jax"):
        import jax.numpy as jnp

        xp = jnp
    else:
        xp = np
        wx, wy = np.asarray(wx), np.asarray(wy)
    if s == 1:  # single data row: tile (wy, offset)
        ones = xp.ones_like(wx, dtype=bool)
        return wy, o + xp.zeros_like(wy), ones
    # fold mask: k = (h1 - wy) >> 31 interpreted as 0/1 (paper Eq. 19)
    k = ((h1 - wy) >> 31) & 1
    # triangle part (k == 0)
    tx, ty = hmap2_full(wx, xp.minimum(wy, h1), s)
    # box part (k == 1): linear cell l = (wy - (s+1)) * s/2 + wx over o*s box
    l = (wy - (s + 1)) * (s // 2) + wx
    bx = l % xp.maximum(o, 1)
    by = l // xp.maximum(o, 1)
    x = xp.where(k == 1, bx, o + tx)
    y_local = xp.where(k == 1, by, ty)
    y = o + y_local
    valid = y_local < (s - t.overshoot)
    return x, y, valid


def total_grid_cells(n: int, threshold: int = 4) -> int:
    return sum(t.grid_cells for t in decompose(n, threshold))
