"""General m-simplex self-similar sets (paper §6).

Implements the volume recurrence Eq. 27-29, the extra-space fraction
Eq. 30 (Lemma 6.1), and the (r, beta) parameter optimization of
Theorem 6.2: finding an efficient self-similar set S_n^m for Delta_n^m
is an optimization over integer 1/r and beta with constraints
beta > 1, 1/r > beta.

The paper's headline: with r = 1/2, beta = 2 the set is efficient only
for m = 2, 3 (extra space m!/(2^m - 2) - 1); choosing r = m^(-1/m) makes
the asymptotic parallel-space saving the full m!, trading a larger
minimum problem size n0(beta).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Tuple

__all__ = [
    "self_similar_volume",
    "alpha_extra_space",
    "alpha_r_half_beta_2",
    "potential_speedup",
    "optimize_r_beta",
    "n0_coverage",
    "best_r_beta",
    "RBeta",
]


def self_similar_volume(n: int, m: int, inv_r: int = 2, beta: int = 2) -> int:
    """V(S_n^m) by direct expansion of the recurrence (Eq. 27):

        V(S_n^m) = (rn)^m + beta * V(S_{rn}^m),   r = 1/inv_r

    evaluated exactly in integers for n a power of inv_r.
    """
    v = 0
    mult = 1
    while n >= inv_r:
        n_next = n // inv_r
        v += mult * (n_next**m)
        mult *= beta
        n = n_next
    return v


def alpha_extra_space(m: int, inv_r: int = 2, beta: int = 2) -> float:
    """lim_{n->inf} V(S)/V(Delta) - 1.

    From Eq. 29: V(S) -> n^m / (inv_r^m - beta)  (when inv_r^m > beta),
    and V(Delta) -> n^m / m!, so alpha = m!/(inv_r^m - beta) - 1 (Eq. 30
    generalized).  Returns inf when the geometric series diverges.
    """
    denom = inv_r**m - beta
    if denom <= 0:
        return math.inf
    return math.factorial(m) / denom - 1.0


def alpha_r_half_beta_2(m: int) -> float:
    """Eq. 30: alpha = m!/(2^m - 2) - 1 for the r=1/2, beta=2 scheme."""
    return alpha_extra_space(m, inv_r=2, beta=2)


def potential_speedup(m: int, inv_r: int = 2, beta: int = 2) -> float:
    """Parallel-space ratio BB/S — the paper's 'potential speedup' (<= m!)."""
    return math.factorial(m) / (1.0 + alpha_extra_space(m, inv_r, beta))


@dataclass(frozen=True)
class RBeta:
    """One feasible (1/r, beta) lattice point of the Thm 6.2 optimization."""

    inv_r: int
    beta: int
    alpha: float  # asymptotic extra space fraction
    n0: int  # first power of inv_r from which V(S) >= V(Delta)
    speedup: float  # BB / V(S) asymptotic


def n0_coverage(m: int, inv_r: int, beta: int, n_max: int = 1 << 22) -> int:
    """Smallest n = inv_r^k with V(S_n^m) >= V(Delta_n^m) (coverage can
    begin), or 0 if none below n_max.  The paper: n0 grows with m and
    shrinks as beta grows — the trade-off of Thm 6.2."""
    n = inv_r
    while n <= n_max:
        v_s = self_similar_volume(n, m, inv_r, beta)
        v_d = math.comb(n + m - 1, m)
        if v_s >= v_d:
            return n
        n *= inv_r
    return 0


def optimize_r_beta(
    m: int, max_inv_r: int = 64, max_beta: int = 64, n_max: int = 1 << 22
) -> List[RBeta]:
    """Thm 6.2: minimize |V(S) - V(Delta)| asymptotically over integer
    (1/r, beta) with beta > 1, 1/r^m > beta.  Returns candidates sorted by
    extra space then n0.  The paper's suggestion r = m^(-1/m) corresponds
    to inv_r^m ~= m... the closest integer lattice points dominate."""
    out: List[RBeta] = []
    for inv_r in range(2, max_inv_r + 1):
        for beta in range(2, max_beta + 1):
            if inv_r**m <= beta:
                continue  # diverging series
            a = alpha_extra_space(m, inv_r, beta)
            if a < 0:  # undercovers asymptotically -> cannot map all of Delta
                continue
            n0 = n0_coverage(m, inv_r, beta, n_max)
            if n0 == 0:
                continue
            out.append(
                RBeta(inv_r, beta, a, n0, potential_speedup(m, inv_r, beta))
            )
    out.sort(key=lambda rb: (rb.alpha, rb.n0))
    return out


def best_r_beta(m: int, constructible: bool = False) -> Tuple[int, int]:
    """Best (1/r, beta) for dimension m.

    ``constructible=False`` — the unconstrained Thm 6.2 optimum over the
    integer lattice (minimal asymptotic extra space, then minimal n0).
    These are *feasibility* optima: for m >= 4 the winners (e.g.
    (3, 57) at m=4, alpha=0) have no known explicit bijective map.

    ``constructible=True`` — restrict to parameters for which an explicit
    map is implemented: the orthant-partition family (2, m) realized by
    ``hmap.hmap_m_recursive`` (extra space m!/(2^m - m) - 1).  For m=2
    this coincides with the paper's optimum (2, 2) at zero waste; for
    m=3 it is the octant map (20%).  Closing the gap between the two is
    a ROADMAP open item.
    """
    if constructible:
        assert 2**m > m, "orthant family converges for all m >= 1"
        return 2, m
    cands = optimize_r_beta(m)
    if not cands:
        raise ValueError(f"no feasible (r, beta) for m={m}")
    return cands[0].inv_r, cands[0].beta
