"""Core library: the paper's m-simplex block-space maps and schedules."""

from . import general_m, hmap, maps_baseline, schedule, simplex, trapezoids
from .hmap import (
    hmap2,
    hmap2_full,
    hmap2_inverse,
    hmap3_octant,
    hmap3_paper,
    pow2_floor,
)
from .schedule import Schedule2D, folded_causal_pairs, grid_steps
from .simplex import simplex_volume, tet, tri

__all__ = [
    "general_m",
    "hmap",
    "maps_baseline",
    "schedule",
    "simplex",
    "trapezoids",
    "hmap2",
    "hmap2_full",
    "hmap2_inverse",
    "hmap3_octant",
    "hmap3_paper",
    "pow2_floor",
    "Schedule2D",
    "folded_causal_pairs",
    "grid_steps",
    "simplex_volume",
    "tet",
    "tri",
]
