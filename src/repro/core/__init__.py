"""Core library: the paper's m-simplex block-space maps and schedules."""

from . import general_m, hmap, maps_baseline, schedule, simplex, trapezoids
from .general_m import alpha_extra_space, best_r_beta
from .hmap import (
    hmap2,
    hmap2_full,
    hmap2_inverse,
    hmap3_octant,
    hmap3_paper,
    hmap_m_grid_size,
    hmap_m_recursive,
    pow2_floor,
)
from .schedule import (
    Schedule2D,
    SimplexSchedule,
    folded_causal_pairs,
    grid_steps,
    registered_kinds,
    resolve_kind,
)
from .simplex import simplex_volume, tet, tri

__all__ = [
    "general_m",
    "hmap",
    "maps_baseline",
    "schedule",
    "simplex",
    "trapezoids",
    "alpha_extra_space",
    "best_r_beta",
    "hmap2",
    "hmap2_full",
    "hmap2_inverse",
    "hmap3_octant",
    "hmap3_paper",
    "hmap_m_grid_size",
    "hmap_m_recursive",
    "pow2_floor",
    "Schedule2D",
    "SimplexSchedule",
    "folded_causal_pairs",
    "grid_steps",
    "registered_kinds",
    "resolve_kind",
    "simplex_volume",
    "tet",
    "tri",
]
