"""Standard discrete m-simplex domains (paper §2).

The standard discrete m-simplex of side ``n`` is

    Delta_n^m = { x in Z_+^m : 0 <= x_i <= n  and  sum(x) <= n }        (Eq. 3)

This module provides the exact volume formulas (simplicial polytopic
numbers, Eq. 4/5/7/20), membership predicates, and small-n enumeration
utilities used by tests and by the table-driven schedulers.

Conventions used throughout the code base
-----------------------------------------
* ``T(n)``      — the *strict* simplex ``{x in Z_+^m : sum(x) < n}``; its
                  cardinality equals ``V(Delta_n^m)`` of the paper (Eq. 4),
                  i.e. ``C(n+m-1, m)``.
* ``tri(n)``    — triangular number n(n+1)/2  = |T^2(n)|.
* ``tet(n)``    — tetrahedral number n(n+1)(n+2)/6 = |T^3(n)|.
* lower-triangular block sets for causal attention use matrix convention
  ``{(col, row): col <= row}`` (inclusive diagonal) or ``col < row``
  (strict); helpers below convert.
"""

from __future__ import annotations

import math
from functools import lru_cache

import numpy as np

__all__ = [
    "simplex_volume",
    "tri",
    "tet",
    "in_simplex",
    "enumerate_simplex",
    "enumerate_lower_triangle",
    "bounding_box_volume",
    "bb_overhead",
]


def simplex_volume(n: int, m: int) -> int:
    """V(Delta_n^m) = C(n+m-1, m)  (Eq. 4) — number of points with sum < n.

    Equivalent to the ``n``-th m-dimensional simplicial polytopic number.
    """
    if n <= 0:
        return 0
    return math.comb(n + m - 1, m)


def tri(n: int) -> int:
    """Triangular numbers — V(Delta_n^2) = n(n+1)/2  (Eq. 7)."""
    return n * (n + 1) // 2


def tet(n: int) -> int:
    """Tetrahedral numbers — V(Delta_n^3) = n(n+1)(n+2)/6  (Eq. 20)."""
    return n * (n + 1) * (n + 2) // 6


def in_simplex(x, n: int) -> bool:
    """Membership in the strict simplex T(n) = {x >= 0, sum(x) < n}."""
    arr = np.asarray(x)
    return bool((arr >= 0).all() and arr.sum() < n)


@lru_cache(maxsize=64)
def enumerate_simplex(n: int, m: int) -> np.ndarray:
    """All points of T(n) in Z^m, lexicographic. O(V) memory — tests only."""
    if m == 1:
        return np.arange(n, dtype=np.int64)[:, None]
    pts = []
    for first in range(n):
        rest = enumerate_simplex(n - first, m - 1)
        block = np.concatenate(
            [np.full((len(rest), 1), first, dtype=np.int64), rest], axis=1
        )
        pts.append(block)
    return np.concatenate(pts, axis=0)


def enumerate_lower_triangle(n: int, strict: bool = False) -> np.ndarray:
    """(col, row) pairs of the lower triangle of an n x n grid.

    ``strict=False`` includes the diagonal: {(x, y): x <= y} — the causal
    attention tile set.  ``strict=True`` gives {(x, y): x < y} — the image
    of the paper's 2-simplex map (Thm 4.3).
    """
    cols, rows = np.meshgrid(np.arange(n), np.arange(n), indexing="xy")
    mask = cols < rows if strict else cols <= rows
    return np.stack([cols[mask], rows[mask]], axis=1).astype(np.int64)


def bounding_box_volume(n: int, m: int) -> int:
    """Parallel space of the bounding-box approach: n^m threads/blocks."""
    return n**m


def bb_overhead(m: int) -> float:
    """lim_{n->inf} V(BB)/V(Delta) - 1 = m! - 1   (Eq. 6)."""
    return math.factorial(m) - 1.0
