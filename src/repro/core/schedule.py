"""Grid schedules: how a Pallas/TPU grid walks an m-simplex domain.

This is the hardware-adaptation layer (DESIGN.md §2): on TPU the paper's
"thread map" becomes the *grid -> data-tile schedule*, realized either as
pure index arithmetic inside a ``BlockSpec.index_map`` (the faithful H
form) or as small scalar-prefetch coordinate tables (the TPU-idiomatic
exact form — one int32 per block, fetched by the scalar core, negligible
next to tile compute).

``SimplexSchedule(m, n, kind)`` is the one entry point (DESIGN.md §2.2):
a registry keyed by (dimension, kind) resolves the walk, and every
schedule exposes the same surface —

    .grid    grid dimensions the kernel launches (tuple)
    .steps   total grid steps (the paper's "parallel space")
    .useful  simplex cells the walk must cover, V(Delta^m_n)
    .map     (*w) -> (*coords, valid); dual-backend (numpy / jax tracers)
    .table() host-side (steps, m+1) int32 walk table for inspection
    .waste() steps/useful - 1, the measured extra parallel space

Registered kinds
----------------
* m=2: ``hmap`` (zero-waste H grid), ``rb`` (RB fold [37]), ``bb``
  (bounding box + predicate), ``table`` (scalar-prefetch exact walk),
  ``composite`` (general-n trapezoid/shell pieces, zero waste at m=2).
* m=3: ``hmap``/``octant`` (r=1/2, beta=3 recursion, ~20% waste),
  ``table`` (0% waste), ``bb``, ``composite`` (any n, analytical).
* m>=4: ``hmap`` (orthant recursion, (1/r, beta) from
  ``general_m.best_r_beta(m, constructible=True)``), ``table``, ``bb``,
  ``composite``.

``composite`` (DESIGN.md §4.2) serves *arbitrary* n at every m: the
simplex decomposes into a power-of-two core plus sheared-prism shell
pieces (core/trapezoids.py), concatenated into one linear grid whose
map is pure index arithmetic — host-side construction is O(pieces),
never the O(V) enumeration the ``table`` kind pays.

``folded_causal_pairs`` — the load-balanced causal sequence-parallel
partition: query-tile i pairs with n-1-i so every pair owns (n+1) KV
tiles — equal triangle *area* per shard.  This is the paper's
parallel-space-balancing argument applied to sharding.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from . import hmap as H
from .general_m import alpha_extra_space, best_r_beta
from .simplex import enumerate_simplex, simplex_volume, tet, tri
from .trapezoids import composite_map, decompose_simplex, piece_map

__all__ = [
    "SimplexSchedule",
    "register_schedule",
    "registered_kinds",
    "resolve_kind",
    "step_grid_indices",
    "Schedule2D",
    "schedule2d_table",
    "schedule3d_table",
    "folded_causal_pairs",
    "grid_steps",
]


def step_grid_indices(sched) -> Tuple[np.ndarray, ...]:
    """Per-axis grid indices of every step — the pass-visible enumeration.

    The static-analysis passes (``repro.analysis``, DESIGN.md §9) replay
    a schedule's walk without launching Pallas by feeding these arrays
    straight into ``sched.map`` — exactly the linearization the kernels
    use (grid axis 0 fastest; for m=2 grids ``(w, h)``: wy-major, wx
    within).  Works for any object with the schedule surface
    (``SimplexSchedule``, ``_PieceSchedule``, ``ShardSchedule``).

    Args:
        sched: Any schedule exposing ``.grid`` and ``.steps``.

    Returns:
        One int64 array of length ``sched.steps`` per grid axis.

    Example:
        >>> ws = step_grid_indices(SimplexSchedule(2, 4, "hmap"))
        >>> len(ws), ws[0].shape
        (2, (10,))
    """
    lin = np.arange(sched.steps, dtype=np.int64)
    ws = []
    for g in sched.grid:
        ws.append(lin % g)
        lin = lin // g
    return tuple(ws)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class _Spec:
    """Resolved schedule: what a kernel needs to launch the walk."""

    grid: Tuple[int, ...]
    map_fn: Callable  # (*w[, tab_ref]) -> (*coords, valid)
    useful: int
    # lazy scalar-prefetch payload for table-driven walks, else None —
    # a thunk so that reading .steps/.waste() never pays the O(V)
    # enumeration (grid_steps on large n must stay arithmetic)
    table_builder: Optional[Callable[[], np.ndarray]] = field(default=None)
    # asymptotic extra-space fraction of this kind (inf-n limit), if known
    alpha: Optional[float] = field(default=None)


# (m | None, kind) -> builder(m, n) -> _Spec.  None entries serve any m
# without an exact (m, kind) registration (the general-m fallbacks).
_REGISTRY: Dict[Tuple[Optional[int], str], Callable[[int, int], _Spec]] = {}


def register_schedule(m: Optional[int], kind: str):
    """Register a schedule builder for a (dimension, kind) pair.

    Args:
        m: Exact dimension the builder serves, or ``None`` to register a
            dimension-generic fallback used by any m without an exact
            ``(m, kind)`` entry.
        kind: Schedule kind name (e.g. ``"hmap"``, ``"composite"``).

    Returns:
        A decorator that records ``builder(m, n) -> _Spec`` in the
        registry and returns it unchanged.  Usage::

            @register_schedule(None, "mykind")
            def _build_mykind(m, n) -> _Spec: ...

    Example:
        >>> "hmap" in registered_kinds(2)  # builders self-register at import
        True
    """

    def _deco(builder):
        _REGISTRY[(m, kind)] = builder
        return builder

    return _deco


def registered_kinds(m: int) -> Tuple[str, ...]:
    """Kinds available for dimension m (exact + generic registrations).

    Args:
        m: Simplex dimension.

    Returns:
        Sorted tuple of kind names ``SimplexSchedule(m, n, kind)``
        accepts at this dimension.

    Example:
        >>> registered_kinds(4)
        ('bb', 'composite', 'hmap', 'table')
    """
    kinds = {k for mm, k in _REGISTRY if mm == m or mm is None}
    return tuple(sorted(kinds))


def resolve_kind(m: int, n: int, kind: str, backend: Optional[str] = None) -> str:
    """Kernel-facing kind resolution (the §4.1 power-of-two constraint).

    'hmap' requires a power-of-two tile count.  For non-pow2 n the
    analytical answer is the §4.2 decomposition: at m >= 3 the requested
    recursion resolves to ``'composite'`` — the general-n piecewise map
    (core/trapezoids.py), one linear grid, O(pieces) host-side cost.  At
    m = 2 the dedicated (w, h)-grid kernels instead fall back to RB
    (exact for any even n) or BB (odd n); the m=2 composite kind exists
    for linear-grid consumers and analysis.

    ``kind='auto'`` delegates to the ``repro.autotune`` subsystem
    (DESIGN.md §5): the schedule is picked per (m, n, backend) from the
    roofline cost model plus any recorded ``BENCH_maps.json``
    measurements, and the decision is cached on disk — kernels and
    benchmarks never hand-pick a schedule.

    Args:
        m: Simplex dimension of the kernel's domain.
        n: Tile count per side (the kernel-facing problem size).
        kind: Requested schedule kind, or ``'auto'``.
        backend: Backend name for autotuned resolution (None = active).

    Returns:
        The kind actually constructible at this (m, n) — ``kind`` itself
        whenever it is exact there.

    Example:
        >>> resolve_kind(3, 6, "hmap"), resolve_kind(4, 100, "hmap")
        ('composite', 'composite')
        >>> resolve_kind(4, 16, "hmap"), resolve_kind(2, 6, "hmap")
        ('hmap', 'rb')
    """
    if kind == "auto":
        from repro.autotune import choose_kind

        kind = choose_kind(m, n, backend=backend).kind
    pow2 = n >= 2 and (n & (n - 1)) == 0
    if m == 2:
        if kind == "hmap" and not pow2:
            kind = "rb" if n % 2 == 0 else "bb"
        if kind == "rb" and n % 2 != 0:
            kind = "bb"
        return kind
    if kind in ("hmap", "octant") and not pow2:
        return "composite"
    return kind


class SimplexSchedule:
    """A grid walk over the discrete m-simplex of side n (in tile units).

    The unified scheduling layer: 2-simplex, 3-simplex and general-m
    walks behind one registry-based API (module docstring for the kind
    table).  Kernel-side, ``.grid``/``.map`` plug straight into Pallas
    ``grid=``/``BlockSpec.index_map``; table-driven kinds additionally
    ship ``.prefetch`` through ``PrefetchScalarGridSpec`` and their
    ``.map`` takes the prefetched ref as a trailing argument.

    Args (constructor):
        m: Simplex dimension, m >= 2.
        n: Side length in tile units (any n >= 1 for ``composite``/
            ``table``/``bb``; power-of-two for the ``hmap`` recursions).
        kind: Registered kind name; see ``registered_kinds(m)``.

    Example:
        >>> sched = SimplexSchedule(3, 6, "composite")  # non-pow2 n
        >>> sched.steps, sched.useful, round(sched.waste(), 3)
        (72, 56, 0.286)
        >>> tab = sched.table()  # (steps, m+1): (*coords, valid)
        >>> tab.shape
        (72, 4)
    """

    def __init__(self, m: int, n: int, kind: str = "hmap"):
        builder = _REGISTRY.get((m, kind)) or _REGISTRY.get((None, kind))
        if builder is None or m < 2:
            raise ValueError(
                f"no schedule registered for m={m}, kind={kind!r}; "
                f"available: {registered_kinds(m) if m >= 2 else ()}"
            )
        self.m = m
        self.n = n
        self.kind = kind
        self._spec = builder(m, n)
        self._table_cache: Optional[np.ndarray] = None

    # -- launch surface ----------------------------------------------------

    @property
    def grid(self) -> Tuple[int, ...]:
        """Grid dimensions to launch (``(w, h)`` for 2-D walks, else linear)."""
        return self._spec.grid

    @property
    def steps(self) -> int:
        """Total grid steps — the paper's "parallel space" (O(1) arithmetic)."""
        s = 1
        for g in self._spec.grid:
            s *= g
        return s

    @property
    def useful(self) -> int:
        """Simplex cells the walk must cover, ``V(Delta^m_n)``."""
        return self._spec.useful

    @property
    def needs_table(self) -> bool:
        """True when this kind walks a host-built scalar-prefetch table."""
        return self._spec.table_builder is not None

    @property
    def prefetch(self) -> Optional[np.ndarray]:
        """Scalar-prefetch payload for table-driven walks (else None).

        Built lazily on first access and cached — `.steps`/`.waste()`
        stay O(1) arithmetic even for table kinds at large n.
        """
        if self._spec.table_builder is None:
            return None
        if self._table_cache is None:
            self._table_cache = self._spec.table_builder()
        return self._table_cache

    def map(self, *w):
        """Map grid coordinates to data-tile coordinates.

        Args:
            *w: One index/array per grid axis (fastest axis first); for
                table-driven kinds, the prefetched table ref last.

        Returns:
            ``(*coords, valid)`` — m data coordinates plus the validity
            flag.  Dual-backend (numpy arrays or jax tracers).

        Example:
            >>> import numpy as np
            >>> s = SimplexSchedule(2, 4, "hmap")
            >>> x, y, v = s.map(np.arange(2), np.zeros(2, np.int64))
            >>> x.tolist(), y.tolist(), v.tolist()
            ([0, 1], [0, 1], [True, True])
        """
        return self._spec.map_fn(*w)

    # -- accounting --------------------------------------------------------

    def waste(self) -> float:
        """Measured extra parallel space at this n.

        Returns:
            ``steps/useful - 1`` — 0.0 for exact (zero-waste) walks.

        Example:
            >>> SimplexSchedule(2, 100, "composite").waste()
            0.0
        """
        return self.steps / self.useful - 1.0

    def asymptotic_waste(self) -> Optional[float]:
        """inf-n extra-space fraction of this kind (None if unknown).

        Returns:
            The registered alpha: exact limit for single-map kinds, an
            upper bound for ``composite`` (whose measured waste at
            non-pow2 n is typically far lower — the shell pieces are
            lower-dimensional).
        """
        return self._spec.alpha

    # -- host-side enumeration ---------------------------------------------

    def table(self) -> np.ndarray:
        """(steps, m+1) int32 walk table: (*coords, valid) per grid step.

        Step order matches the linearization kernels use: grid axis 0
        fastest (for m=2 grids (w, h): wy-major, wx within).
        """
        if self.needs_table:
            tab = self.prefetch
            valid = np.ones((len(tab), 1), dtype=np.int32)
            return np.concatenate([tab.astype(np.int32), valid], axis=1)
        ws = step_grid_indices(self)
        out = self.map(*ws)
        coords, valid = out[:-1], out[-1]
        cols = [np.asarray(c) for c in coords]
        cols.append(np.asarray(valid).astype(np.int64))
        return np.stack(cols, axis=1).astype(np.int32)

    # -- per-piece launch splitting (composite only) -----------------------

    def split_pieces(self) -> Tuple["object", ...]:
        """Per-piece sub-schedules of a composite walk.

        A composite schedule's branchless map decodes every piece per
        evaluated index (O(pieces) selects per grid step).  Splitting
        returns one lightweight schedule per piece — same ``.grid`` /
        ``.steps`` / ``.map`` surface, each map decoding only its own
        factor chain — so a kernel can launch one ``pallas_call`` per
        piece when the select chain would dominate
        (``repro.autotune.should_split_pieces`` is the heuristic).

        Returns:
            Tuple of per-piece schedules for ``kind='composite'``;
            ``(self,)`` for every other kind (nothing to split).

        Example:
            >>> subs = SimplexSchedule(3, 6, "composite").split_pieces()
            >>> sum(s.steps for s in subs)
            72
        """
        if self.kind != "composite":
            return (self,)
        pieces = decompose_simplex(self.m, self.n)
        return tuple(
            _PieceSchedule(self.m, self.n, p, i) for i, p in enumerate(pieces)
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"SimplexSchedule(m={self.m}, n={self.n}, kind={self.kind!r}, "
            f"grid={self.grid}, steps={self.steps}, useful={self.useful})"
        )


class _PieceSchedule:
    """One piece of a split composite schedule (see ``split_pieces``).

    Exposes the subset of the ``SimplexSchedule`` surface kernels
    consume for linear walks: ``.grid``, ``.steps``, ``.useful``,
    ``.map`` (piece-local linear index -> global coords + valid) and a
    ``.prefetch`` that is always None (pure index arithmetic).
    """

    kind = "composite-piece"

    def __init__(self, m: int, n: int, piece, index: int):
        self.m = m
        self.n = n
        self.piece = piece
        self.index = index
        self.grid = (piece.grid_cells,)
        self.steps = piece.grid_cells
        self.useful = piece.data_cells
        self.prefetch = None

    def map(self, lin):
        """Piece-local linear index -> ``(*coords, valid)`` (global)."""
        out = piece_map(self.piece, self.m, lin)
        if self.m != 2:
            return out
        u, v, ok = out
        return u, (self.n - 1) - v, ok  # match the m=2 composite flip

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"_PieceSchedule(m={self.m}, n={self.n}, piece={self.index}, "
            f"steps={self.steps})"
        )


def _ones_like(x):
    if type(x).__module__.startswith("jax"):
        import jax.numpy as jnp

        return jnp.ones_like(x, dtype=bool)
    return np.ones_like(np.asarray(x), dtype=bool)


# ---------------------------------------------------------------------------
# 2-simplex builders
# ---------------------------------------------------------------------------


@register_schedule(2, "hmap")
def _build2_hmap(m: int, n: int) -> _Spec:
    assert n >= 2 and (n & (n - 1)) == 0, (
        "hmap needs power-of-two n (paper §4.1); use the trapezoid "
        "decomposition (§4.2) for general n"
    )

    def fn(wx, wy):
        x, y = H.hmap2_full(wx, wy, n)
        return x, y, _ones_like(x)

    return _Spec((n // 2, n + 1), fn, tri(n), alpha=0.0)


@register_schedule(2, "rb")
def _build2_rb(m: int, n: int) -> _Spec:
    assert n % 2 == 0 and n >= 2

    def fn(wx, wy):
        from .maps_baseline import rb_map2

        x, y = rb_map2(wx, wy, n)
        return x, y, _ones_like(x)

    return _Spec((n // 2, n + 1), fn, tri(n), alpha=0.0)


@register_schedule(2, "bb")
def _build2_bb(m: int, n: int) -> _Spec:
    def fn(wx, wy):
        return wx, wy, wx <= wy

    return _Spec((n, n), fn, tri(n), alpha=1.0)


@register_schedule(2, "table")
def _build2_table(m: int, n: int) -> _Spec:
    def fn(lin, tab_ref):
        return tab_ref[lin, 0], tab_ref[lin, 1], _one(lin)

    return _Spec(
        (tri(n),), fn, tri(n),
        table_builder=lambda: schedule2d_table(n), alpha=0.0,
    )


# ---------------------------------------------------------------------------
# 3-simplex builders
# ---------------------------------------------------------------------------


@register_schedule(3, "table")
def _build3_table(m: int, n: int) -> _Spec:
    def fn(lin, tab_ref):
        return tab_ref[lin, 0], tab_ref[lin, 1], tab_ref[lin, 2], _one(lin)

    return _Spec(
        (tet(n),), fn, tet(n),
        table_builder=lambda: schedule3d_table(n), alpha=0.0,
    )


# ---------------------------------------------------------------------------
# general-m builders (serve m=3 'hmap'/'octant' and every m >= 4)
# ---------------------------------------------------------------------------


def _build_md_hmap(m: int, n: int) -> _Spec:
    inv_r, beta = best_r_beta(m, constructible=True)
    steps = H.hmap_m_grid_size(n, m, inv_r, beta)

    def fn(lin):
        return H.hmap_m_recursive(lin, n, m, inv_r, beta)

    return _Spec(
        (steps,),
        fn,
        simplex_volume(n, m),
        alpha=alpha_extra_space(m, inv_r, beta),
    )


register_schedule(None, "hmap")(_build_md_hmap)
register_schedule(3, "octant")(_build_md_hmap)


@register_schedule(None, "table")
def _build_md_table(m: int, n: int) -> _Spec:
    def fn(lin, tab_ref):
        return tuple(tab_ref[lin, j] for j in range(m)) + (_one(lin),)

    v = simplex_volume(n, m)
    return _Spec(
        (v,), fn, v,
        table_builder=lambda: enumerate_simplex(n, m).astype(np.int32),
        alpha=0.0,
    )


@register_schedule(None, "composite")
def _build_composite(m: int, n: int) -> _Spec:
    """General-n composite schedule: pow2 core + shell pieces, one grid.

    Pieces from ``trapezoids.decompose_simplex`` are concatenated into a
    single linear grid; the map selects the piece by static prefix
    offsets and decodes its power-of-two factor chain (all index
    arithmetic — usable as a Pallas index_map, no scalar prefetch).  At
    m=2 the strict-sum coordinates are flipped into the repo's
    (col, row) lower-triangle convention; every m=2 factor has dim <= 2
    so the m=2 composite is exactly zero waste (the trapezoid scheme).
    For m >= 3 the asymptotic extra space is bounded by the core
    recursion's alpha; measured waste at non-pow2 n sits well below it
    (shell pieces are lower-dimensional).
    """
    pieces = decompose_simplex(m, n)
    steps = sum(p.grid_cells for p in pieces)

    if m == 2:

        def fn(lin):
            u, v, ok = composite_map(pieces, 2, lin)
            return u, (n - 1) - v, ok  # strict (u, v) -> (col, row)

    else:

        def fn(lin):
            return composite_map(pieces, m, lin)

    alpha = 0.0 if m == 2 else alpha_extra_space(m, 2, m)
    return _Spec((steps,), fn, simplex_volume(n, m), alpha=alpha)


@register_schedule(None, "bb")
def _build_md_bb(m: int, n: int) -> _Spec:
    import math

    def fn(lin):
        coords = []
        rem = lin
        for _ in range(m):
            coords.append(rem % n)
            rem = rem // n
        total = coords[0]
        for c in coords[1:]:
            total = total + c
        return tuple(coords) + (total < n,)

    return _Spec(
        (n**m,), fn, simplex_volume(n, m), alpha=math.factorial(m) - 1.0
    )


def _one(lin):
    if type(lin).__module__.startswith("jax"):
        import jax.numpy as jnp

        return jnp.ones_like(jnp.asarray(lin), dtype=jnp.bool_)
    return np.ones_like(np.asarray(lin), dtype=bool)


# ---------------------------------------------------------------------------
# deprecated 2D shim + host tables
# ---------------------------------------------------------------------------


class Schedule2D:
    """Deprecated thin shim over ``SimplexSchedule(2, n, kind)``.

    kind='hmap':  zero-waste (n/2, n+1) grid, paper Eq. 14-16 + our
                  diagonal rows; tile = (col, row) with col <= row.
    kind='rb':    zero-waste (n/2, n+1) grid, RB fold [37].
    kind='bb':    (n, n) bounding box + validity predicate (the baseline).
    """

    def __init__(self, n: int, kind: str = "hmap"):
        warnings.warn(
            "Schedule2D is deprecated; use SimplexSchedule(2, n, kind)",
            DeprecationWarning,
            stacklevel=2,
        )
        assert kind in ("hmap", "rb", "bb")
        self.n = n
        self.kind = kind
        self._s = SimplexSchedule(2, n, kind)

    @property
    def grid(self) -> Tuple[int, int]:
        """(width, height) of the delegated ``SimplexSchedule(2, ...)``."""
        return self._s.grid

    @property
    def steps(self) -> int:
        """Total grid steps of the delegated schedule."""
        return self._s.steps

    @property
    def useful(self) -> int:
        """Lower-triangle tiles to cover, ``tri(n)``."""
        return self._s.useful

    def map(self, wx, wy):
        """Delegate to ``SimplexSchedule.map``: (wx, wy) -> (x, y, valid)."""
        return self._s.map(wx, wy)

    def table(self) -> np.ndarray:
        """Delegate to ``SimplexSchedule.table()``."""
        return self._s.table()


def schedule2d_table(n: int) -> np.ndarray:
    """Exact (tri(n), 2) int32 table of lower-triangle tiles, diagonal-first
    order (diagonal tiles first so masked tiles are contiguous).

    Args:
        n: Tile count per side.

    Returns:
        ``(tri(n), 2)`` int32 array of (col, row) pairs — the O(V)
        scalar-prefetch payload of the m=2 ``table`` kind.

    Example:
        >>> schedule2d_table(2).tolist()
        [[0, 0], [1, 1], [0, 1]]
    """
    cols, rows = [], []
    for y in range(n):
        cols.append(y)
        rows.append(y)
    for y in range(n):
        for x in range(y):
            cols.append(x)
            rows.append(y)
    return np.stack([np.array(cols), np.array(rows)], 1).astype(np.int32)


def schedule3d_table(n: int) -> np.ndarray:
    """Exact (tet(n), 3) int32 table of T(n) tiles (zero waste, the
    TPU-idiomatic scalar-prefetch form).

    Args:
        n: Tile count per side.

    Returns:
        ``(tet(n), 3)`` int32 array of (x, y, z) with x+y+z < n, x
        fastest — the O(V) scalar-prefetch payload of the m=3 ``table``
        kind.

    Example:
        >>> schedule3d_table(2).tolist()
        [[0, 0, 0], [1, 0, 0], [0, 1, 0], [0, 0, 1]]
    """
    pts = []
    for z in range(n):
        for y in range(n - z):
            for x in range(n - z - y):
                pts.append((x, y, z))
    arr = np.asarray(pts, dtype=np.int32)
    assert len(arr) == tet(n)
    return arr


def folded_causal_pairs(n_tiles: int) -> np.ndarray:
    """Folded pairs (i, n-1-i): the equal-area causal partition.

    Each pair owns ``i+1 + n-i = n+1`` KV tiles — the load-balanced
    fold used for sequence-parallel sharding and by the flash kernel's
    folded grid (its k-way generalization to any dimension is
    ``distributed.simplex_sharding.fold_partition``).  An odd tile
    count self-pairs the middle tile: the last row is ``[mid, mid]``
    and owns only ``mid+1`` KV tiles — callers that require the
    constant ``n+1``-tile balance (the folded flash grid) must reject
    odd counts instead of consuming the short row.

    Args:
        n_tiles: Number of query tiles, >= 1.

    Returns:
        ``(ceil(n_tiles/2), 2)`` int32 array of folded query-tile
        pairs; for odd ``n_tiles`` the final row is the self-paired
        middle tile.

    Example:
        >>> folded_causal_pairs(4).tolist()
        [[0, 3], [1, 2]]
        >>> folded_causal_pairs(5).tolist()
        [[0, 4], [1, 3], [2, 2]]
    """
    if n_tiles < 1:
        raise ValueError(f"n_tiles must be >= 1, got {n_tiles}")
    i = np.arange((n_tiles + 1) // 2, dtype=np.int32)
    return np.stack([i, n_tiles - 1 - i], 1)


def grid_steps(n: int, kind: str, m: int = 2) -> int:
    """Grid steps each schedule launches — the paper's 'parallel space'.

    The MAP-test speedup claim is the BB/steps ratio of these numbers.

    Args:
        n: Tile count per side.
        kind: Registered kind, or ``"paper"`` at m=3 for the literal
            Eq. 26 grid shape.
        m: Simplex dimension (default 2).

    Returns:
        Total grid steps of ``SimplexSchedule(m, n, kind)``.

    Example:
        >>> grid_steps(16, "hmap"), grid_steps(16, "bb")
        (136, 256)
    """
    if m == 3 and kind == "paper":
        w, h, d = H.hmap3_paper_grid_shape(n)
        return w * h * d
    return SimplexSchedule(m, n, kind).steps
