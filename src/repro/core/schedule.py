"""Grid schedules: how a Pallas/TPU grid walks an m-simplex domain.

This is the hardware-adaptation layer (DESIGN.md §2): on TPU the paper's
"thread map" becomes the *grid -> data-tile schedule*, realized either as
pure index arithmetic inside a ``BlockSpec.index_map`` (the faithful H
form) or as small scalar-prefetch coordinate tables (the TPU-idiomatic
exact form — one int32 per block, fetched by the scalar core, negligible
next to tile compute).

``SimplexSchedule(m, n, kind)`` is the one entry point (DESIGN.md §2.2):
a registry keyed by (dimension, kind) resolves the walk, and every
schedule exposes the same surface —

    .grid    grid dimensions the kernel launches (tuple)
    .steps   total grid steps (the paper's "parallel space")
    .useful  simplex cells the walk must cover, V(Delta^m_n)
    .map     (*w) -> (*coords, valid); dual-backend (numpy / jax tracers)
    .table() host-side (steps, m+1) int32 walk table for inspection
    .waste() steps/useful - 1, the measured extra parallel space

Registered kinds
----------------
* m=2: ``hmap`` (zero-waste H grid), ``rb`` (RB fold [37]), ``bb``
  (bounding box + predicate), ``table`` (scalar-prefetch exact walk).
* m=3: ``hmap``/``octant`` (r=1/2, beta=3 recursion, ~20% waste),
  ``table`` (0% waste), ``bb``.
* m>=4: ``hmap`` (orthant recursion, (1/r, beta) from
  ``general_m.best_r_beta(m, constructible=True)``), ``table``, ``bb``.

``folded_causal_pairs`` — the load-balanced causal sequence-parallel
partition: query-tile i pairs with n-1-i so every pair owns (n+1) KV
tiles — equal triangle *area* per shard.  This is the paper's
parallel-space-balancing argument applied to sharding.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from . import hmap as H
from .general_m import alpha_extra_space, best_r_beta
from .simplex import enumerate_simplex, simplex_volume, tet, tri

__all__ = [
    "SimplexSchedule",
    "register_schedule",
    "registered_kinds",
    "resolve_kind",
    "Schedule2D",
    "schedule2d_table",
    "schedule3d_table",
    "folded_causal_pairs",
    "grid_steps",
]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class _Spec:
    """Resolved schedule: what a kernel needs to launch the walk."""

    grid: Tuple[int, ...]
    map_fn: Callable  # (*w[, tab_ref]) -> (*coords, valid)
    useful: int
    # lazy scalar-prefetch payload for table-driven walks, else None —
    # a thunk so that reading .steps/.waste() never pays the O(V)
    # enumeration (grid_steps on large n must stay arithmetic)
    table_builder: Optional[Callable[[], np.ndarray]] = field(default=None)
    # asymptotic extra-space fraction of this kind (inf-n limit), if known
    alpha: Optional[float] = field(default=None)


# (m | None, kind) -> builder(m, n) -> _Spec.  None entries serve any m
# without an exact (m, kind) registration (the general-m fallbacks).
_REGISTRY: Dict[Tuple[Optional[int], str], Callable[[int, int], _Spec]] = {}


def register_schedule(m: Optional[int], kind: str):
    """Register a schedule builder for (dimension, kind); ``m=None``
    registers a dimension-generic fallback."""

    def deco(builder):
        _REGISTRY[(m, kind)] = builder
        return builder

    return deco


def registered_kinds(m: int) -> Tuple[str, ...]:
    """Kinds available for dimension m (exact + generic registrations)."""
    kinds = {k for mm, k in _REGISTRY if mm == m or mm is None}
    return tuple(sorted(kinds))


def resolve_kind(m: int, n: int, kind: str) -> str:
    """Kernel-facing kind resolution (the §4.1 power-of-two constraint).

    'hmap' requires a power-of-two tile count; general n is served by the
    concurrent-trapezoid decomposition (§4.2, core/trapezoids.py — one
    pallas_call per piece).  For a single-call kernel on non-pow2 n we
    fall back to RB (exact for any even n, m=2), the exact table walk
    (m >= 3), or BB — the production shapes are pow2.
    """
    pow2 = n >= 2 and (n & (n - 1)) == 0
    if m == 2:
        if kind == "hmap" and not pow2:
            kind = "rb" if n % 2 == 0 else "bb"
        if kind == "rb" and n % 2 != 0:
            kind = "bb"
        return kind
    if kind in ("hmap", "octant") and not pow2:
        return "table"
    return kind


class SimplexSchedule:
    """A grid walk over the discrete m-simplex of side n (in tile units).

    The unified scheduling layer: 2-simplex, 3-simplex and general-m
    walks behind one registry-based API (module docstring for the kind
    table).  Kernel-side, ``.grid``/``.map`` plug straight into Pallas
    ``grid=``/``BlockSpec.index_map``; table-driven kinds additionally
    ship ``.prefetch`` through ``PrefetchScalarGridSpec`` and their
    ``.map`` takes the prefetched ref as a trailing argument.
    """

    def __init__(self, m: int, n: int, kind: str = "hmap"):
        builder = _REGISTRY.get((m, kind)) or _REGISTRY.get((None, kind))
        if builder is None or m < 2:
            raise ValueError(
                f"no schedule registered for m={m}, kind={kind!r}; "
                f"available: {registered_kinds(m) if m >= 2 else ()}"
            )
        self.m = m
        self.n = n
        self.kind = kind
        self._spec = builder(m, n)
        self._table_cache: Optional[np.ndarray] = None

    # -- launch surface ----------------------------------------------------

    @property
    def grid(self) -> Tuple[int, ...]:
        return self._spec.grid

    @property
    def steps(self) -> int:
        s = 1
        for g in self._spec.grid:
            s *= g
        return s

    @property
    def useful(self) -> int:
        return self._spec.useful

    @property
    def needs_table(self) -> bool:
        return self._spec.table_builder is not None

    @property
    def prefetch(self) -> Optional[np.ndarray]:
        """Scalar-prefetch payload for table-driven walks (else None).

        Built lazily on first access and cached — `.steps`/`.waste()`
        stay O(1) arithmetic even for table kinds at large n.
        """
        if self._spec.table_builder is None:
            return None
        if self._table_cache is None:
            self._table_cache = self._spec.table_builder()
        return self._table_cache

    def map(self, *w):
        """(*w) -> (*coords, valid).  Dual-backend; for table-driven
        kinds the last argument is the prefetched table ref."""
        return self._spec.map_fn(*w)

    # -- accounting --------------------------------------------------------

    def waste(self) -> float:
        """Measured extra parallel space at this n: steps/useful - 1."""
        return self.steps / self.useful - 1.0

    def asymptotic_waste(self) -> Optional[float]:
        """inf-n extra-space fraction of this kind (None if unknown)."""
        return self._spec.alpha

    # -- host-side enumeration ---------------------------------------------

    def table(self) -> np.ndarray:
        """(steps, m+1) int32 walk table: (*coords, valid) per grid step.

        Step order matches the linearization kernels use: grid axis 0
        fastest (for m=2 grids (w, h): wy-major, wx within).
        """
        if self.needs_table:
            tab = self.prefetch
            valid = np.ones((len(tab), 1), dtype=np.int32)
            return np.concatenate([tab.astype(np.int32), valid], axis=1)
        lin = np.arange(self.steps, dtype=np.int64)
        ws = []
        for g in self.grid:
            ws.append(lin % g)
            lin = lin // g
        out = self.map(*ws)
        coords, valid = out[:-1], out[-1]
        cols = [np.asarray(c) for c in coords]
        cols.append(np.asarray(valid).astype(np.int64))
        return np.stack(cols, axis=1).astype(np.int32)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"SimplexSchedule(m={self.m}, n={self.n}, kind={self.kind!r}, "
            f"grid={self.grid}, steps={self.steps}, useful={self.useful})"
        )


def _ones_like(x):
    if type(x).__module__.startswith("jax"):
        import jax.numpy as jnp

        return jnp.ones_like(x, dtype=bool)
    return np.ones_like(np.asarray(x), dtype=bool)


# ---------------------------------------------------------------------------
# 2-simplex builders
# ---------------------------------------------------------------------------


@register_schedule(2, "hmap")
def _build2_hmap(m: int, n: int) -> _Spec:
    assert n >= 2 and (n & (n - 1)) == 0, (
        "hmap needs power-of-two n (paper §4.1); use the trapezoid "
        "decomposition (§4.2) for general n"
    )

    def fn(wx, wy):
        x, y = H.hmap2_full(wx, wy, n)
        return x, y, _ones_like(x)

    return _Spec((n // 2, n + 1), fn, tri(n), alpha=0.0)


@register_schedule(2, "rb")
def _build2_rb(m: int, n: int) -> _Spec:
    assert n % 2 == 0 and n >= 2

    def fn(wx, wy):
        from .maps_baseline import rb_map2

        x, y = rb_map2(wx, wy, n)
        return x, y, _ones_like(x)

    return _Spec((n // 2, n + 1), fn, tri(n), alpha=0.0)


@register_schedule(2, "bb")
def _build2_bb(m: int, n: int) -> _Spec:
    def fn(wx, wy):
        return wx, wy, wx <= wy

    return _Spec((n, n), fn, tri(n), alpha=1.0)


@register_schedule(2, "table")
def _build2_table(m: int, n: int) -> _Spec:
    def fn(lin, tab_ref):
        return tab_ref[lin, 0], tab_ref[lin, 1], _one(lin)

    return _Spec(
        (tri(n),), fn, tri(n),
        table_builder=lambda: schedule2d_table(n), alpha=0.0,
    )


# ---------------------------------------------------------------------------
# 3-simplex builders
# ---------------------------------------------------------------------------


@register_schedule(3, "table")
def _build3_table(m: int, n: int) -> _Spec:
    def fn(lin, tab_ref):
        return tab_ref[lin, 0], tab_ref[lin, 1], tab_ref[lin, 2], _one(lin)

    return _Spec(
        (tet(n),), fn, tet(n),
        table_builder=lambda: schedule3d_table(n), alpha=0.0,
    )


# ---------------------------------------------------------------------------
# general-m builders (serve m=3 'hmap'/'octant' and every m >= 4)
# ---------------------------------------------------------------------------


def _build_md_hmap(m: int, n: int) -> _Spec:
    inv_r, beta = best_r_beta(m, constructible=True)
    steps = H.hmap_m_grid_size(n, m, inv_r, beta)

    def fn(lin):
        return H.hmap_m_recursive(lin, n, m, inv_r, beta)

    return _Spec(
        (steps,),
        fn,
        simplex_volume(n, m),
        alpha=alpha_extra_space(m, inv_r, beta),
    )


register_schedule(None, "hmap")(_build_md_hmap)
register_schedule(3, "octant")(_build_md_hmap)


@register_schedule(None, "table")
def _build_md_table(m: int, n: int) -> _Spec:
    def fn(lin, tab_ref):
        return tuple(tab_ref[lin, j] for j in range(m)) + (_one(lin),)

    v = simplex_volume(n, m)
    return _Spec(
        (v,), fn, v,
        table_builder=lambda: enumerate_simplex(n, m).astype(np.int32),
        alpha=0.0,
    )


@register_schedule(None, "bb")
def _build_md_bb(m: int, n: int) -> _Spec:
    import math

    def fn(lin):
        coords = []
        rem = lin
        for _ in range(m):
            coords.append(rem % n)
            rem = rem // n
        total = coords[0]
        for c in coords[1:]:
            total = total + c
        return tuple(coords) + (total < n,)

    return _Spec(
        (n**m,), fn, simplex_volume(n, m), alpha=math.factorial(m) - 1.0
    )


def _one(lin):
    if type(lin).__module__.startswith("jax"):
        import jax.numpy as jnp

        return jnp.ones_like(jnp.asarray(lin), dtype=jnp.bool_)
    return np.ones_like(np.asarray(lin), dtype=bool)


# ---------------------------------------------------------------------------
# deprecated 2D shim + host tables
# ---------------------------------------------------------------------------


class Schedule2D:
    """Deprecated thin shim over ``SimplexSchedule(2, n, kind)``.

    kind='hmap':  zero-waste (n/2, n+1) grid, paper Eq. 14-16 + our
                  diagonal rows; tile = (col, row) with col <= row.
    kind='rb':    zero-waste (n/2, n+1) grid, RB fold [37].
    kind='bb':    (n, n) bounding box + validity predicate (the baseline).
    """

    def __init__(self, n: int, kind: str = "hmap"):
        warnings.warn(
            "Schedule2D is deprecated; use SimplexSchedule(2, n, kind)",
            DeprecationWarning,
            stacklevel=2,
        )
        assert kind in ("hmap", "rb", "bb")
        self.n = n
        self.kind = kind
        self._s = SimplexSchedule(2, n, kind)

    @property
    def grid(self) -> Tuple[int, int]:
        return self._s.grid

    @property
    def steps(self) -> int:
        return self._s.steps

    @property
    def useful(self) -> int:
        return self._s.useful

    def map(self, wx, wy):
        return self._s.map(wx, wy)

    def table(self) -> np.ndarray:
        return self._s.table()


def schedule2d_table(n: int) -> np.ndarray:
    """Exact (tri(n), 2) int32 table of lower-triangle tiles, diagonal-first
    order (diagonal tiles first so masked tiles are contiguous)."""
    cols, rows = [], []
    for y in range(n):
        cols.append(y)
        rows.append(y)
    for y in range(n):
        for x in range(y):
            cols.append(x)
            rows.append(y)
    return np.stack([np.array(cols), np.array(rows)], 1).astype(np.int32)


def schedule3d_table(n: int) -> np.ndarray:
    """Exact (tet(n), 3) int32 table of T(n) tiles (zero waste, the
    TPU-idiomatic scalar-prefetch form)."""
    pts = []
    for z in range(n):
        for y in range(n - z):
            for x in range(n - z - y):
                pts.append((x, y, z))
    arr = np.asarray(pts, dtype=np.int32)
    assert len(arr) == tet(n)
    return arr


def folded_causal_pairs(n_tiles: int) -> np.ndarray:
    """(n_tiles/2, 2) pairs (i, n-1-i): each pair owns i+1 + n-i = n+1 KV
    tiles — the equal-area causal partition used for sequence-parallel
    sharding and by the flash kernel's folded grid."""
    assert n_tiles % 2 == 0
    i = np.arange(n_tiles // 2, dtype=np.int32)
    return np.stack([i, n_tiles - 1 - i], 1)


def grid_steps(n: int, kind: str, m: int = 2) -> int:
    """Grid steps each schedule launches — the paper's 'parallel space'.

    The MAP-test speedup claim is the BB/steps ratio of these numbers.
    """
    if m == 3 and kind == "paper":
        w, h, d = H.hmap3_paper_grid_shape(n)
        return w * h * d
    return SimplexSchedule(m, n, kind).steps
