"""Grid schedules: how a Pallas/TPU grid walks a simplex domain.

This is the hardware-adaptation layer (DESIGN.md §2): on TPU the paper's
"thread map" becomes the *grid -> data-tile schedule*, realized either as
pure index arithmetic inside a ``BlockSpec.index_map`` (the faithful H
form) or as small scalar-prefetch coordinate tables (the TPU-idiomatic
exact form — one int32 per block, fetched by the scalar core, negligible
next to tile compute).

Schedules provided
------------------
* ``Schedule2D('hmap' | 'rb' | 'bb')``        — 2-simplex tile walks
* ``schedule3d_table`` / ``'octant'`` / 'bb'  — 3-simplex tile walks
* ``folded_causal_pairs``                     — the load-balanced causal
  sequence-parallel partition: query-tile i pairs with n-1-i so every
  pair owns (n+1) KV tiles — equal triangle *area* per shard.  This is
  the paper's parallel-space-balancing argument applied to sharding.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np

from . import hmap as H
from .simplex import tet, tri

__all__ = [
    "Schedule2D",
    "schedule2d_table",
    "schedule3d_table",
    "folded_causal_pairs",
    "grid_steps",
]


@dataclass(frozen=True)
class Schedule2D:
    """A walk over the inclusive lower triangle of an n x n tile grid.

    kind='hmap':  zero-waste (n/2, n+1) grid, paper Eq. 14-16 + our
                  diagonal rows; tile = (col, row) with col <= row.
    kind='rb':    zero-waste (n/2, n+1) grid, RB fold [37].  Row-major
                  consecutive KV visits per query tile — the schedule the
                  flash-attention kernel uses (running softmax needs
                  consecutive visits; see kernels/flash_attention.py).
    kind='bb':    (n, n) bounding box + validity predicate (the baseline).
    """

    n: int
    kind: str = "hmap"

    def __post_init__(self):
        assert self.kind in ("hmap", "rb", "bb")
        if self.kind == "hmap":
            assert self.n >= 2 and (self.n & (self.n - 1)) == 0, (
                "hmap needs power-of-two n (paper §4.1); use the "
                "trapezoid decomposition (§4.2) for general n"
            )
        if self.kind == "rb":
            assert self.n % 2 == 0 and self.n >= 2

    @property
    def grid(self) -> Tuple[int, int]:
        if self.kind == "bb":
            return self.n, self.n
        return self.n // 2, self.n + 1

    @property
    def steps(self) -> int:
        w, h = self.grid
        return w * h

    @property
    def useful(self) -> int:
        return tri(self.n)

    def map(self, wx, wy):
        """(wx, wy) -> (col, row, valid); dual-backend, branchless."""
        if self.kind == "hmap":
            x, y = H.hmap2_full(wx, wy, self.n)
            valid = _ones_like(x)
            return x, y, valid
        if self.kind == "rb":
            from .maps_baseline import rb_map2

            x, y = rb_map2(wx, wy, self.n)
            valid = _ones_like(x)
            return x, y, valid
        x, y = wx, wy
        return x, y, x <= y

    def table(self) -> np.ndarray:
        """Host-side (steps, 3) int32 table of (col, row, valid)."""
        w, h = self.grid
        wy, wx = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
        x, y, v = self.map(wx.ravel(), wy.ravel())
        return np.stack(
            [np.asarray(x), np.asarray(y), np.asarray(v).astype(np.int64)], 1
        ).astype(np.int32)


def _ones_like(x):
    if type(x).__module__.startswith("jax"):
        import jax.numpy as jnp

        return jnp.ones_like(x, dtype=bool)
    return np.ones_like(np.asarray(x), dtype=bool)


def schedule2d_table(n: int) -> np.ndarray:
    """Exact (tri(n), 2) int32 table of lower-triangle tiles, diagonal-first
    order (diagonal tiles first so masked tiles are contiguous)."""
    cols, rows = [], []
    for y in range(n):
        cols.append(y)
        rows.append(y)
    for y in range(n):
        for x in range(y):
            cols.append(x)
            rows.append(y)
    return np.stack([np.array(cols), np.array(rows)], 1).astype(np.int32)


def schedule3d_table(n: int) -> np.ndarray:
    """Exact (tet(n), 3) int32 table of T(n) tiles (zero waste, the
    TPU-idiomatic scalar-prefetch form)."""
    pts = []
    for z in range(n):
        for y in range(n - z):
            for x in range(n - z - y):
                pts.append((x, y, z))
    arr = np.asarray(pts, dtype=np.int32)
    assert len(arr) == tet(n)
    return arr


def folded_causal_pairs(n_tiles: int) -> np.ndarray:
    """(n_tiles/2, 2) pairs (i, n-1-i): each pair owns i+1 + n-i = n+1 KV
    tiles — the equal-area causal partition used for sequence-parallel
    sharding and by the flash kernel's folded grid."""
    assert n_tiles % 2 == 0
    i = np.arange(n_tiles // 2, dtype=np.int32)
    return np.stack([i, n_tiles - 1 - i], 1)


def grid_steps(n: int, kind: str, m: int = 2) -> int:
    """Grid steps each schedule launches — the paper's 'parallel space'.

    The MAP-test speedup claim is the BB/steps ratio of these numbers.
    """
    if m == 2:
        return Schedule2D(n, kind).steps if kind != "table" else tri(n)
    if m == 3:
        if kind == "bb":
            return n**3
        if kind == "octant":
            return H.hmap3_octant_grid_size(n)
        if kind == "table":
            return tet(n)
        if kind == "paper":
            w, h, d = H.hmap3_paper_grid_shape(n)
            return w * h * d
    raise ValueError((n, kind, m))
