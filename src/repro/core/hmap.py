"""The paper's block-space map  H : Z^m -> Z^m  (§4).

Everything here is expressed with integer / bit operations only
(Definition 4.1): no roots, no float transcendentals.  All functions are
*dual-backend*: they accept either numpy arrays / python ints (host-side
grid construction, oracles) or jax tracers (usable inside
``pl.BlockSpec`` index_maps and kernel bodies).

2-simplex (Thm 4.3, verified bijection)
---------------------------------------
Grid (super-orthotope) ``Pi^2_{n/2, n-1}``, block coordinate
``w = (wx, wy)`` with ``wx in [0, n/2)``, ``wy in [1, n-1]``:

    b = 2^floor(log2 wy)          (Eq. 14, via clz — Eq. 17/18)
    q = wx // b                   (Eq. 15)
    H(w) = (wx + q*b, wy + 2*q*b) (Eq. 16)

maps bijectively onto the strict lower triangle {(x, y): 0 <= x < y <= n-1}
(n a power of two).  ``V(Pi) = n/2 * (n-1) = V(Delta^2_{n-1})`` — zero waste.

Zero-waste inclusive-diagonal extension (ours)
----------------------------------------------
The paper leaves ``wy = 0`` undefined (log2).  We use it: grid
``(n/2, n+1)`` where row 0 carries the first half of the diagonal and row
``n`` the second half — a bijection onto {(x, y): x <= y <= n-1} with
*exactly* ``n(n+1)/2`` grid blocks.  This is the form used by the causal
attention and simplex kernels (diagonal tiles are the only ones needing
an intra-tile mask, and they are identified by the grid row — no
per-tile predicate anywhere).

3-simplex
---------
``hmap3_paper`` implements Eq. 26 literally.  Calibration (see
``tests/test_core_maps.py::test_hmap3_paper_literal_coverage_documented``
and DESIGN.md §3) shows the printed equation is under-determined by the
text (~30% coverage under the literal reading, geometry lives in the
paper's figures).  The production 3D scheduler is ``hmap3_octant`` — an
*exact* self-similar map (r=1/2, beta=3 octant recursion; same
machinery, provably bijective) — plus the table-driven scheduler in
``core/schedule.py`` (0% waste, the TPU-idiomatic form).

General m-simplex (§6, constructive)
------------------------------------
``hmap_m_recursive`` generalizes the octant recursion to any m >= 2 via
the orthant partition (r = 1/2, beta = m):

    T^m(n) = ([0, n/2)^m ∩ T^m(n))  ⊎  ⊎_{i=1..m} (T^m(n/2) + n/2·e_i)

(a point can have at most one coordinate >= n/2 since the sum is < n,
and subtracting n/2 from that coordinate lands it in T^m(n/2)).  This is
the first *constructed* member of the paper's Thm 6.2 family for m >= 4;
its extra space is ``alpha_extra_space(m, 2, m) = m!/(2^m - m) - 1``
(m=2: 0%, m=3: 20%, m=4: 100% — still m!/(1+alpha) ~ 12x less parallel
space than the bounding box at m=4).  ``hmap3_octant`` is the m=3
instance.
"""

from __future__ import annotations

from typing import Any, Tuple

import numpy as np

__all__ = [
    "pow2_floor",
    "floor_log2",
    "hmap2",
    "hmap2_full",
    "hmap2_inverse",
    "hmap2_grid_shape",
    "hmap2_full_grid_shape",
    "hmap3_paper",
    "hmap3_paper_grid_shape",
    "octant_levels",
    "hmap3_octant",
    "hmap3_octant_grid_size",
    "hmap_m_recursive",
    "hmap_m_grid_size",
    "hmap_factor",
    "hmap_factor_grid_size",
]


def _is_jax(*xs: Any) -> bool:
    for x in xs:
        if type(x).__module__.startswith("jax"):
            return True
    return False


def pow2_floor(y):
    """Largest power of two <= y  (y >= 1).  Bit-smear: Eq. 14 without logs.

    Works identically for numpy ints/arrays and jax tracers (int32/int64).
    On TPU the jax path could equivalently use ``1 << (31 - lax.clz(y))``
    (Eq. 17/18); the smear lowers to the same scalar-unit ops and is
    backend-agnostic, so it is the default.
    """
    y = y | (y >> 1)
    y = y | (y >> 2)
    y = y | (y >> 4)
    y = y | (y >> 8)
    y = y | (y >> 16)
    return y - (y >> 1)


def floor_log2(y):
    """floor(log2(y)) via clz when traced by jax (Eq. 17), bit_length on host."""
    if _is_jax(y):
        import jax.numpy as jnp
        from jax import lax

        y32 = jnp.asarray(y, dtype=jnp.int32)
        return (31 - lax.clz(y32)).astype(jnp.int32)
    y_arr = np.asarray(y)
    if y_arr.ndim == 0:
        return int(y_arr).bit_length() - 1
    out = np.frompyfunc(lambda v: int(v).bit_length() - 1, 1, 1)(y_arr)
    return out.astype(np.int64)


def hmap2(wx, wy) -> Tuple[Any, Any]:
    """Eq. 14-16: super-orthotope block (wx, wy) -> strict lower triangle.

    Domain: wx in [0, n/2), wy in [1, n-1], n a power of two.
    Image:  {(x, y) : 0 <= x < y <= n-1}, bijective.
    """
    b = pow2_floor(wy)
    q = wx // b
    return wx + q * b, wy + 2 * q * b


def hmap2_full(wx, wy, n: int) -> Tuple[Any, Any]:
    """Zero-waste inclusive-diagonal map: grid (n/2, n+1) -> {x <= y <= n-1}.

    Branchless (select-based) so it is usable inside Pallas index_maps.
    Row 0:   (wx, wx)                 — first half of the diagonal
    Row n:   (n/2 + wx, n/2 + wx)     — second half of the diagonal
    Rows 1..n-1: Eq. 16 strict map.
    """
    if _is_jax(wx, wy):
        import jax.numpy as jnp

        wy_safe = jnp.where((wy >= 1) & (wy <= n - 1), wy, 1)
        x_s, y_s = hmap2(wx, wy_safe)
        diag0 = wy == 0
        diagn = wy == n
        x = jnp.where(diag0, wx, jnp.where(diagn, n // 2 + wx, x_s))
        y = jnp.where(diag0, wx, jnp.where(diagn, n // 2 + wx, y_s))
        return x, y
    wx = np.asarray(wx)
    wy = np.asarray(wy)
    wy_safe = np.where((wy >= 1) & (wy <= n - 1), wy, 1)
    x_s, y_s = hmap2(wx, wy_safe)
    diag0 = wy == 0
    diagn = wy == n
    x = np.where(diag0, wx, np.where(diagn, n // 2 + wx, x_s))
    y = np.where(diag0, wx, np.where(diagn, n // 2 + wx, y_s))
    return x, y


def hmap2_inverse(x, y) -> Tuple[Any, Any]:
    """Inverse of ``hmap2`` (strict lower triangle -> super-orthotope).

    The level-b orthotope q covers data x in [2qb, (2q+1)b),
    y in [(2q+1)b, (2q+2)b): x and y share all bits above position
    log2(b) and differ exactly at that bit (the HODLR block-pair
    identity), so  b = pow2_floor(x XOR y),  q = x // (2b).
    Integer/bit ops only.
    """
    b = pow2_floor(x ^ y)
    q = x // (2 * b)
    return x - q * b, y - 2 * q * b


def hmap2_grid_shape(n: int) -> Tuple[int, int]:
    """(width, height) of the strict-map super-orthotope Pi^2_{n/2, n-1}."""
    return n // 2, n - 1


def hmap2_full_grid_shape(n: int) -> Tuple[int, int]:
    """(width, height) of the zero-waste inclusive-diagonal grid."""
    return n // 2, n + 1


# ---------------------------------------------------------------------------
# 3-simplex
# ---------------------------------------------------------------------------


def hmap3_paper_grid_shape(n: int) -> Tuple[int, int, int]:
    """Pi^3_{n/2, n/2, 3(n-1)/4} (Thm 4.6)."""
    return n // 2, n // 2, 3 * (n - 1) // 4 + 1


def hmap3_paper(wx, wy, wz, n: int):
    """Eq. 26, literal reading.  Returns (x, y, z, valid).

    The text under-determines the packing geometry (see module docstring);
    this literal form is kept for the calibration benchmark.  ``valid`` is
    1 where the candidate position lands inside T(n) = {sum < n} and no
    case matched twice; callers must predicate on it.
    """
    xp: Any
    if _is_jax(wx, wy, wz):
        import jax.numpy as jnp

        xp = jnp
    else:
        xp = np
        wx, wy, wz = np.asarray(wx), np.asarray(wy), np.asarray(wz)
    half = n // 2
    wy_safe = xp.where(wy >= 1, wy, 1)
    b = pow2_floor(wy_safe)
    q = wx // b
    # case 1: the displaced major cube, h(w) = w + (0, n/2, 0)
    c1 = wz < half
    x1, y1, z1 = wx, wy + half, wz
    # case 2: direct self-similar placement
    x2, y2, z2 = wx + q * b, wy + 2 * q * b, wz - half
    in2 = (x2 + y2 + z2) < n
    # case 3: hinge reflection for blocks outside Delta
    x3 = b * (1 + 2 * q) - wx
    y3 = 2 * b * (1 + q) - wy
    z3 = 2 * b - wz + half
    x = xp.where(c1, x1, xp.where(in2, x2, x3))
    y = xp.where(c1, y1, xp.where(in2, y2, y3))
    z = xp.where(c1, z1, xp.where(in2, z2, z3))
    valid = (x >= 0) & (y >= 0) & (z >= 0) & ((x + y + z) < n)
    return x, y, z, valid


# ---------------------------------------------------------------------------
# Exact m-simplex map: orthant recursion (r = 1/2, beta = m), ours.
#
#   T(n) = ([0,n/2)^m ∩ T(n))  ⊎  ⊎_{i=1..m} (T(n/2) + n/2·e_i)
#
# (exact partition — proof: a point with x_i >= n/2 satisfies
#  (x_i-n/2) + rest < n/2 iff sum < n, and two coordinates >= n/2 would
#  violate sum < n; verified constructively in tests).
#
# Flattened: level k = 1..K-1 has m^(k-1) cubes of side s_k = n/2^k
# (the near-cube of a T(n/2^(k-1)) sub-simplex; cells with local sum >=
# 2*s_k are the dead far-corner hole).  The terminal level K has m^(K-1)
# cubes of side 2 covering their T(2) sub-simplex *entirely* (m+1 of 2^m
# cells valid).  For m=3 the total grid is ~n^3/5 vs V = n^3/6 (~20%
# extra, vs +500% for BB); asymptotically the extra space is
# alpha_extra_space(m, 2, m) = m!/(2^m - m) - 1.  All index arithmetic
# is integer ops with a fixed <= 30-level unroll — usable inside Pallas
# index_maps like hmap2.
# ---------------------------------------------------------------------------


def octant_levels(n: int) -> int:
    """Number of levels K = log2(n); the terminal level has side-2 cubes."""
    assert n >= 2 and (n & (n - 1)) == 0, "recursive map requires power-of-two n"
    return n.bit_length() - 1


def _recursive_level_sizes(n: int, m: int):
    """Per-level (count, side) pairs; terminal level has side 2."""
    K = octant_levels(n)
    out = []
    for k in range(1, K):
        out.append((m ** (k - 1), n >> k))
    out.append((m ** (K - 1), 2))  # terminal: covers T(2) fully
    return out


def _check_r_beta(m: int, inv_r: int, beta) -> int:
    beta = m if beta is None else beta
    if inv_r != 2 or beta != m:
        raise NotImplementedError(
            f"no explicit construction for (1/r, beta) = ({inv_r}, {beta}) at "
            f"m={m}; only the orthant partition (2, {m}) has a known "
            "bijective map (DESIGN.md §4, ROADMAP open items)"
        )
    return beta


def hmap_m_grid_size(n: int, m: int, inv_r: int = 2, beta=None) -> int:
    """Total grid cells of the recursive m-simplex map."""
    _check_r_beta(m, inv_r, beta)
    return sum(cnt * side**m for cnt, side in _recursive_level_sizes(n, m))


def hmap_m_recursive(idx, n: int, m: int, inv_r: int = 2, beta=None):
    """Exact linear-grid m-simplex map: idx in [0, grid_size) ->
    (x_0, ..., x_{m-1}, valid).

    Bijective onto T(n) = {sum(x) < n} over the valid cells; dead cells
    (valid=0) are the far-corner holes of each level cube.  Dual-backend
    (numpy ints/arrays or jax tracers).  Only the constructible
    (inv_r, beta) = (2, m) orthant family is implemented; see
    ``general_m.best_r_beta(m, constructible=True)``.
    """
    _check_r_beta(m, inv_r, beta)
    if _is_jax(idx):
        import jax.numpy as jnp

        xp = jnp
        idx = jnp.asarray(idx)  # int32 suffices for block-space grids
    else:
        xp = np
        idx = np.asarray(idx, dtype=np.int64)
    K = octant_levels(n)
    level_specs = _recursive_level_sizes(n, m)
    sizes = [cnt * side**m for cnt, side in level_specs]
    prefix = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)

    # level of this cell: fixed unroll over K levels (K <= 30)
    level = xp.zeros_like(idx)
    for k in range(1, K):
        level = xp.where(idx >= prefix[k], level + 1, level)
    base = xp.zeros_like(idx)
    s = xp.zeros_like(idx)
    bound = xp.zeros_like(idx)
    for lvl, (_, side) in enumerate(level_specs):
        base = xp.where(level == lvl, prefix[lvl], base)
        s = xp.where(level == lvl, side, s)
        # standard levels: valid iff local sum < 2*side (sub-simplex
        # bound); terminal level: the side-2 cube covers T(2) fully,
        # valid iff sum < 2.
        terminal = lvl == K - 1
        bound = xp.where(level == lvl, 2 if terminal else 2 * side, bound)
    rem = idx - base
    c = rem // (s**m)
    p = rem - c * (s**m)
    # local coordinates inside the level cube, x_{m-1} decoded first
    # (slowest axis), x_0 last (fastest) — the 3D (z, y, x) order.
    loc = []
    q = p
    for j in range(m):
        stride = s ** (m - 1 - j)
        lj = q // stride
        q = q - lj * stride
        loc.append(lj)
    loc = loc[::-1]  # loc[j] = local x_j
    # offset from base-m path digits of c: digit j (0-based, j < level)
    # chooses the displacement axis for a step of n >> (j+1).
    offs = [xp.zeros_like(idx) for _ in range(m)]
    cc = c
    for j in range(K - 1):
        active = j < level
        d = cc % m
        step = idx.dtype.type(n >> (j + 1)) if xp is np else (n >> (j + 1))
        for ax in range(m):
            offs[ax] = xp.where(active & (d == ax), offs[ax] + step, offs[ax])
        cc = xp.where(active, cc // m, cc)
    coords = tuple(offs[j] + loc[j] for j in range(m))
    lsum = loc[0]
    for lj in loc[1:]:
        lsum = lsum + lj
    valid = lsum < bound
    return coords + (valid,)


def hmap_factor_grid_size(side: int, dim: int) -> int:
    """Grid cells ``hmap_factor`` launches for a (dim, side) simplex factor.

    Zero waste for dim <= 2 (interval / inclusive-diagonal 2-simplex
    grid); for dim >= 3 the orthant recursion's grid
    (``hmap_m_grid_size``).  O(log side) arithmetic — never O(V).
    """
    if side == 1:
        return 1
    if dim == 1:
        return side
    if dim == 2:
        return (side // 2) * (side + 1)
    return hmap_m_grid_size(side, dim)


def hmap_factor(idx, side: int, dim: int):
    """Offset-aware recursion entry: linear idx -> one T^dim(side) factor.

    The composite (general-n) schedule decomposes a simplex into chained
    power-of-two *factors* (core/trapezoids.py §4.2); this is the single
    decoder every factor uses, dispatching on dimension:

    * ``side == 1`` — the point factor T^d(1) = {0}^d (grid 1).
    * ``dim == 1``  — interval [0, side), identity, any side, zero waste.
    * ``dim == 2``  — strict-sum 2-simplex {u + v < side} through the
      zero-waste inclusive-diagonal grid ``hmap2_full`` (side a power of
      two), flipped by v = side-1-row.
    * ``dim >= 3``  — ``hmap_m_recursive`` (side a power of two).

    Returns ``(c_0, ..., c_{dim-1}, valid)`` with ``sum(c) < side`` on
    valid cells; the factor's local coordinates are exchangeable (the
    domain is symmetric), so callers may apply their shear offset to any
    one output slot.  Dual-backend like every map in this module.
    """
    if side == 1:
        if _is_jax(idx):
            import jax.numpy as jnp

            z = jnp.zeros_like(jnp.asarray(idx))
            return (z,) * dim + (jnp.ones_like(z, dtype=jnp.bool_),)
        z = np.zeros_like(np.asarray(idx, dtype=np.int64))
        return (z,) * dim + (np.ones_like(z, dtype=bool),)
    if dim == 1:
        if _is_jax(idx):
            import jax.numpy as jnp

            idx = jnp.asarray(idx)
            return idx, jnp.ones_like(idx, dtype=jnp.bool_)
        idx = np.asarray(idx, dtype=np.int64)
        return idx, np.ones_like(idx, dtype=bool)
    if dim == 2:
        w = side // 2
        wy = idx // w
        wx = idx - wy * w
        col, row = hmap2_full(wx, wy, side)
        if _is_jax(col):
            import jax.numpy as jnp

            return col, (side - 1) - row, jnp.ones_like(col, dtype=jnp.bool_)
        return col, (side - 1) - row, np.ones_like(np.asarray(col), dtype=bool)
    return hmap_m_recursive(idx, side, dim)


def hmap3_octant_grid_size(n: int) -> int:
    """Total grid cells of the m=3 (octant) instance (~n^3/5)."""
    return hmap_m_grid_size(n, 3)


def hmap3_octant(idx, n: int):
    """Exact linear-grid 3-simplex map: idx in [0, grid_size) -> (x,y,z,valid).

    The m=3 instance of ``hmap_m_recursive`` (r=1/2, beta=3 octant
    recursion).  Kept as a named entry point for the 3D kernels/tests.
    """
    return hmap_m_recursive(idx, n, 3)
