"""Roofline analysis from compiled dry-run artifacts.

Three terms (per-chip seconds), TPU v5e constants:
  compute    = HLO_FLOPs / (chips * 197e12  bf16 FLOP/s)
  memory     = HLO_bytes / (chips * 819e9   B/s HBM)
  collective = wire_bytes_per_chip / 50e9   B/s per ICI link

Collective bytes are NOT in cost_analysis(); they are parsed from the
compiled HLO text: for every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute we read operand & result shapes and the
replica-group size g, then apply ring-transfer formulas for per-chip
wire traffic:
    all-gather      result_bytes * (g-1)/g
    reduce-scatter  operand_bytes * (g-1)/g
    all-reduce      operand_bytes * 2(g-1)/g
    all-to-all      operand_bytes * (g-1)/g
    collective-perm operand_bytes
(cost_analysis FLOPs/bytes are *global* across the mesh; wire bytes here
are per chip already, so the collective term divides by one link's
bandwidth.)

MODEL_FLOPS = 6 * N_active * tokens (the usual dense-training estimate;
fwd-only modes use 2 * N_active * tokens); the ratio MODEL_FLOPS /
HLO_FLOPs shows how much compiled compute is "useful" — remat recompute
and schedule waste push it down.
"""

from __future__ import annotations

import json
import os
import re
from typing import Dict, List

import numpy as np

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B / s / chip
LINK_BW = 50e9  # B / s / ICI link

# -- schedule cost-model constants (per grid step / per launch) -------------
# Scalar-unit costs of the index_map forms on the compiled path; coarse,
# but their *ratios* are what the autotuner ranks kinds by (DESIGN.md §5).
SELECT_S = 1.5e-9  # one branchless select/compare chain element
SMEM_READ_S = 4e-9  # one scalar-prefetch (SMEM) coordinate read
PREDICATE_S = 1.0e-9  # the bb add-compare validity predicate
LAUNCH_OVERHEAD_S = 5e-6  # fixed cost of one extra pallas_call launch
HOST_ENUM_S = 2.5e-8  # host-side per-cell cost of an O(V) table build
TABLE_AMORTIZE = 1000  # launches a built table is amortized over
# Attention entries (DESIGN.md §8): per-block-pair scalar overheads of
# the three causal-attention executors choose_attn_impl ranks.
ATTN_FOLD_SELECT_S = 2 * SELECT_S  # the _folded_qkv where/compare pair
ATTN_GATHER_S = SMEM_READ_S  # chunked-XLA per-step tile gather/scatter
# Per-grid-step cost of the Pallas *interpreter* (emulated index_maps +
# per-block dispatch) — the term that sends huge grids to the chunked
# XLA path on interpret-only backends.
INTERPRET_STEP_S = 2e-5

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUP_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLL_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

__all__ = [
    "collective_census",
    "roofline_terms",
    "load_cells",
    "wire_bytes",
    "schedule_cost_model",
]


def schedule_cost_model(
    kind: str,
    steps: int,
    *,
    m: int,
    n: int,
    useful: int,
    pieces: int = 1,
    rho: int = 8,
    dtype_bytes: int = 4,
    hbm_bw: float = HBM_BW,
    head_dim: int = 0,
) -> float:
    """Predicted seconds per launch of one schedule kind (memory-bound).

    The model the ``repro.autotune`` tuner ranks candidate kinds with
    when no measured ``BENCH_maps.json`` row applies.  Two terms:

    * tile traffic — each grid step streams one (rho,)*m tile in and out
      of HBM: ``steps * 2 * rho^m * dtype_bytes / hbm_bw``.  Wasted
      steps (steps > useful) pay full traffic, which is exactly how the
      paper's extra parallel space costs on hardware.
    * index-map overhead — per-step scalar work of the map form:
      ``bb`` one predicate; ``table`` one SMEM read (plus its O(V) host
      build amortized over ``TABLE_AMORTIZE`` launches); ``hmap``/
      ``octant`` a log2(n)-level select chain; ``composite`` an
      O(pieces) select chain (the term the per-piece launch split
      removes — see ``repro.autotune.should_split_pieces``).

    Attention entries (DESIGN.md §8): kinds ``attn-folded`` /
    ``attn-bb`` / ``attn-chunked`` model the causal-attention hot path
    on the 2-simplex tile grid (``steps`` = block-pair visits, ``rho``
    = the square score-tile side, ``head_dim`` = D).  Each step moves
    three ``rho x head_dim`` operand tiles plus the output tile and
    pays two ``rho x rho x head_dim`` MXU matmuls; the per-step scalar
    overhead is the fold select chain (``attn-folded``), the causal
    predicate on every bounding-box step (``attn-bb``), or the XLA
    tile gather/scatter (``attn-chunked``).  This is the analytic
    prior ``repro.autotune.choose_attn_impl`` ranks executors with
    before measured ATTN rows exist.

    Args:
        kind: Registered schedule kind, or an ``attn-*`` entry.
        steps: Grid steps the schedule launches.
        m: Simplex dimension.
        n: Tile count per side.
        useful: Simplex cells covered (V) — table build cost scales on it.
        pieces: Composite piece count (ignored for other kinds).
        rho: Tile side in elements.
        dtype_bytes: Element width.
        hbm_bw: Memory bandwidth to model against.
        head_dim: Attention head dim (``attn-*`` kinds only).

    Returns:
        Predicted seconds for one launch of the full walk.
    """
    if kind.startswith("attn-"):
        d = head_dim or rho
        tile_bytes = (3 * rho * d + rho * d) * dtype_bytes  # q,k,v in + o out
        if kind == "attn-chunked":
            # the XLA realization round-trips the (rho, rho) score tile
            # through HBM between HLO ops; the Pallas kernel keeps it
            # in VMEM — the structural reason flash wins on device.
            tile_bytes += 2 * rho * rho * dtype_bytes
        t_mem = steps * tile_bytes / hbm_bw
        t_mxu = steps * 2 * (2 * rho * rho * d) / PEAK_FLOPS
        per_step = {
            "attn-folded": ATTN_FOLD_SELECT_S,
            "attn-bb": PREDICATE_S,
            "attn-chunked": ATTN_GATHER_S,
        }.get(kind)
        if per_step is None:
            raise ValueError(f"unknown attention cost-model kind {kind!r}")
        return t_mem + t_mxu + steps * per_step
    tile_bytes = 2 * (rho**m) * dtype_bytes  # read + write
    t_mem = steps * tile_bytes / hbm_bw
    if kind == "bb":
        per_step = PREDICATE_S
        build = 0.0
    elif kind == "table":
        per_step = SMEM_READ_S
        build = useful * HOST_ENUM_S / TABLE_AMORTIZE
    elif kind == "composite":
        per_step = SELECT_S * max(pieces, 1)
        build = 0.0
    else:  # hmap / octant / rb: select chain over the recursion levels
        levels = max(int(n - 1).bit_length(), 1)
        per_step = SELECT_S * levels
        build = 0.0
    return t_mem + steps * per_step + build


def _shape_bytes(tok: str) -> int:
    m = _SHAPE_RE.match(tok)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUP_V2_RE.search(line)
    if m:  # iota tile form [num_groups, group_size]
        return max(int(m.group(2)), 1)
    m = _GROUP_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return max(len(first.split(",")), 1)
    return default


def wire_bytes(kind: str, operand: int, result: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return result * (g - 1) / g
    if kind == "reduce-scatter":
        return operand * (g - 1) / g
    if kind == "all-reduce":
        return operand * 2 * (g - 1) / g
    if kind == "all-to-all":
        return operand * (g - 1) / g
    if kind == "collective-permute":
        return operand
    return 0.0


def collective_census(hlo_text: str) -> Dict:
    """Parse the compiled HLO; returns per-op-kind counts/bytes and the
    per-chip wire-byte total.  Robust to both replica_groups syntaxes."""
    per_kind: Dict[str, Dict[str, float]] = {}
    total_wire = 0.0
    for line in hlo_text.splitlines():
        ls = line.strip()
        if not ls or "=" not in ls:
            continue
        m = re.search(r"=\s*(\w+\[[^\]]*\][^ ]*)\s+([a-z0-9-]+)\(", ls)
        if not m:
            continue
        kind = m.group(2)
        # strip -start/-done fusion suffixes (async collectives)
        base = kind.replace("-start", "").replace("-done", "")
        if base not in _COLL_OPS:
            continue
        if kind.endswith("-done"):
            continue  # counted at -start
        result_b = _shape_bytes(m.group(1))
        # operand shapes: inside the call parens
        inner = ls[m.end(2) + 1 :]
        operand_b = sum(
            _shape_bytes(t) for t in re.findall(r"\w+\[[\d,]*\]", inner)
        )
        if operand_b == 0:
            operand_b = result_b
        g = _group_size(ls)
        wb = wire_bytes(base, operand_b, result_b, g)
        k = per_kind.setdefault(
            base, {"count": 0, "operand_bytes": 0.0, "wire_bytes": 0.0}
        )
        k["count"] += 1
        k["operand_bytes"] += operand_b
        k["wire_bytes"] += wb
        total_wire += wb
    return {"per_kind": per_kind, "wire_bytes_per_chip": total_wire}


def roofline_terms(rec: Dict) -> Dict:
    """rec: one dry-run cell JSON record (see launch/dryrun.py).

    The memory term is a BAND: ``memory_floor_s`` is the analytic
    minimum HBM traffic (each chip streams its model-parallel slice of
    the weights once per pass: microbatches x 3 passes for train with
    full remat, 1 pass for prefill/decode — the classic weights-bound
    floor); ``memory_s`` is the loop-aware HLO-granularity upper bound
    (CPU-backend fusion is coarser than TPU's, so real traffic sits in
    between).  Dominance uses the conservative floor.
    """
    chips = rec["n_chips"]
    t_compute = rec["flops"] / (chips * PEAK_FLOPS)
    t_memory_hi = rec["bytes_accessed"] / (chips * HBM_BW)
    model_size = rec.get("model_axis", 16)
    passes = (3 * rec.get("microbatches", 1)) if rec["mode"] == "train" else 1
    param_bytes = rec["params"] * 4.0  # f32 master storage
    floor = passes * param_bytes / model_size / HBM_BW
    t_coll = rec["collectives"]["wire_bytes_per_chip"] / LINK_BW
    terms = {
        "compute_s": t_compute,
        "memory_floor_s": floor,
        "collective_s": t_coll,
    }
    dom = max(terms, key=terms.get)
    factor = 6 if rec["mode"] == "train" else 2
    model_flops = factor * rec["params_active"] * rec["tokens"]
    hlo = max(rec["flops"], 1.0)
    bound = max(terms.values())
    ideal = model_flops / (chips * PEAK_FLOPS)
    return {
        **terms,
        "memory_s": t_memory_hi,  # upper bound (see docstring)
        "dominant": dom.replace("_s", "").replace("_floor", ""),
        "model_flops": model_flops,
        "useful_ratio": model_flops / hlo,
        # fraction of the compute roofline this cell achieves if the
        # dominant (floor-based) term were the runtime — structural MFU
        "roofline_fraction": ideal / bound if bound > 0 else 0.0,
    }


def load_cells(outdir: str, mesh: str) -> List[Dict]:
    d = os.path.join(outdir, mesh)
    cells = []
    if not os.path.isdir(d):
        return cells
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            cells.append(json.load(open(os.path.join(d, f))))
    return cells
