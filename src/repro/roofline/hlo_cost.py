"""Loop-aware cost model over compiled HLO text.

``compiled.cost_analysis()`` (and any single-pass census of the HLO
text) counts each ``while`` body ONCE — a scan-of-layers model with
grad-accum microbatching under-reports FLOPs/bytes/collectives by the
product of its trip counts (verified empirically: a 10-step scanned
matmul reports 1 matmul of FLOPs).  This module parses the compiled HLO
into computations, reads each loop's trip count from the
``backend_config={"known_trip_count":{"n":...}}`` annotation XLA puts on
``while`` ops (fallback: the loop condition's compare constant), and
propagates multipliers through the call graph:

  flops       — ``dot`` ops: 2 * prod(result) * contracted K (operand
                shapes resolved through a per-computation symbol table);
  bytes       — operand + result bytes of materializing top-level ops in
                sequential computations (entry / loop bodies / branches);
                ops inside fused computations stay in registers;
  collectives — the ring-transfer wire model of roofline.analysis,
                multiplied by enclosing trip counts.

Validated against unrolled references in tests/test_roofline.py.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from .analysis import _DTYPE_BYTES, wire_bytes

__all__ = ["analyze_hlo"]

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-_]+)\s*=\s*")
_TOKEN_CH = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789-_$")


def _parse_op(line):
    """(name, result_type, opcode, args_start_idx) or None.

    Types may be tuples containing commas, spaces and even ``/*index=N*/``
    comments with '=' inside, so the opcode is located by scanning for the
    first depth-0 identifier immediately followed by '(' after the '='.
    """
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    depth = 0
    tok_start = None
    for i, c in enumerate(rest):
        if c == "(":
            if depth == 0 and tok_start is not None:
                tok = rest[tok_start:i]
                if tok and not tok[0].isdigit():
                    return name, rest[:tok_start].strip(), tok, m.end() + i + 1
            depth += 1
            tok_start = None
        elif c in ")]}":
            depth -= 1
            tok_start = None
        elif c in "[{":
            depth += 1
            tok_start = None
        elif c in _TOKEN_CH:
            if tok_start is None:
                tok_start = i
        else:
            tok_start = None
    return None
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n":"(\d+)"')
_CALL_RE = re.compile(r"(?:calls|to_apply|condition|body)=%([\w\.\-_]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-_]+)")
_GROUP_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_CONST_CMP = re.compile(r"constant\((\d+)\)")

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")
_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "while",
    "conditional",
}


def _type_bytes_elems(type_str: str) -> Tuple[int, int]:
    """Total bytes and element count of a (possibly tuple) type string."""
    total_b = 0
    total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_b += n * _DTYPE_BYTES[dt]
        total_e += n
    return total_b, total_e


def _first_shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _group_size(line: str) -> int:
    m = _GROUP_V2_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUP_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return max(len(first.split(",")), 1)
    return 2


class _Comp:
    __slots__ = ("name", "flops", "bytes", "wire", "coll", "whiles",
                 "calls", "trip_hint")

    def __init__(self, name):
        self.name = name
        self.flops = 0.0
        self.bytes = 0.0
        self.wire = 0.0
        self.coll: Dict[str, Dict[str, float]] = {}
        self.whiles: List[Tuple[str, int]] = []  # (body, trip)
        self.calls: List[str] = []
        self.trip_hint: Optional[int] = None


def analyze_hlo(text: str) -> Dict:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    entry: Optional[str] = None
    symbols: Dict[str, str] = {}  # %name -> type string (scoped per comp)

    for raw in text.splitlines():
        line = raw.rstrip()
        if line.startswith("HloModule"):
            continue
        head = re.match(
            r"^(ENTRY\s+)?%([\w\.\-_]+)\s*\((.*)\)\s*->", line
        )
        if head and line.endswith("{"):
            cur = _Comp(head.group(2))
            comps[cur.name] = cur
            symbols = {}
            if head.group(1):
                entry = cur.name
            # parameters: "name: type, name: (tuple type)"
            params = head.group(3)
            for pm in re.finditer(r"([\w\.\-_]+):\s*(\(?[^,()]*(?:\([^)]*\))?[^,]*)",
                                  params):
                symbols[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        om = _parse_op(line)
        if not om:
            continue
        name, rtype, opcode, args_idx = om
        symbols[name] = rtype
        rest = line[args_idx:]
        # strip metadata noise for operand parsing
        core = re.split(r"\bmetadata=", rest)[0]
        args_str = core.split(")")[0]
        operand_names = _OPERAND_RE.findall(args_str)
        operand_types = [symbols.get(n, "") for n in operand_names]
        rbytes, _ = _type_bytes_elems(rtype)

        if opcode == "dot":
            dims = _first_shape_dims(rtype)
            res_elems = 1
            for d in dims:
                res_elems *= d
            k = 1
            mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
            lhs_dims = _first_shape_dims(operand_types[0]) if operand_types else []
            if mc and lhs_dims:
                for i in mc.group(1).split(","):
                    if i:
                        k *= lhs_dims[int(i)]
            cur.flops += 2.0 * res_elems * k

        base = opcode.replace("-start", "").replace("-done", "")
        if base in _COLL and not opcode.endswith("-done"):
            ob = sum(_type_bytes_elems(t)[0] for t in operand_types)
            g = _group_size(line)
            if ob == 0:
                ob = rbytes if base != "all-gather" else rbytes // max(g, 1)
            wb = wire_bytes(base, ob, rbytes, g)
            cur.wire += wb
            rec = cur.coll.setdefault(
                base, {"count": 0, "operand_bytes": 0.0, "wire_bytes": 0.0}
            )
            rec["count"] += 1
            rec["operand_bytes"] += ob
            rec["wire_bytes"] += wb

        if opcode == "while":
            bm = re.search(r"body=%([\w\.\-_]+)", rest)
            tm = _TRIP_RE.search(rest)
            trip = int(tm.group(1)) if tm else 0
            cm = re.search(r"condition=%([\w\.\-_]+)", rest)
            if bm:
                cur.whiles.append((bm.group(1), trip))
            if cm:
                cur.calls.append("__cond__" + cm.group(1))
        else:
            for cname in _CALL_RE.findall(rest):
                cur.calls.append(cname)
            bm = _BRANCH_RE.search(rest)
            if bm:
                for cname in bm.group(1).replace("%", "").split(","):
                    cname = cname.strip()
                    if cname:
                        cur.calls.append(cname)

        if opcode not in _SKIP_BYTES:
            cur.bytes += rbytes + sum(
                _type_bytes_elems(t)[0] for t in operand_types
            )

        if "compare(" in line and "direction=LT" in line:
            pass

    # condition-based trip fallback
    for comp in comps.values():
        consts = []
        # (kept cheap: scan only small computations — conditions are tiny)
        comp.trip_hint = None

    # propagate multipliers through the call graph
    mult: Dict[str, float] = defaultdict(float)
    seq: Dict[str, bool] = defaultdict(bool)
    if entry is None and comps:
        entry = next(iter(comps))
    stack = [(entry, 1.0, True)]
    guard = 0
    while stack:
        guard += 1
        if guard > 200000:
            break
        name, m, is_seq = stack.pop()
        if name.startswith("__cond__"):
            name = name[8:]
            comp = comps.get(name)
            if comp is None:
                continue
            mult[name] += m
            continue
        comp = comps.get(name)
        if comp is None:
            continue
        mult[name] += m
        if is_seq:
            seq[name] = True
        for body, trip in comp.whiles:
            stack.append((body, m * max(trip, 1), is_seq))
        for callee in comp.calls:
            stack.append((callee, m, False))

    total_flops = sum(c.flops * mult[c.name] for c in comps.values())
    total_bytes = sum(c.bytes * mult[c.name] for c in comps.values() if seq[c.name])
    total_wire = sum(c.wire * mult[c.name] for c in comps.values())
    # flat (= trip counts ignored) counterparts: the ratio loop/flat is the
    # correction factor to apply to cost_analysis' fusion-aware numbers
    flat_flops = sum(c.flops for c in comps.values() if mult[c.name] > 0)
    flat_bytes = sum(
        c.bytes for c in comps.values() if seq[c.name] and mult[c.name] > 0
    )
    coll: Dict[str, Dict[str, float]] = {}
    for c in comps.values():
        for k, v in c.coll.items():
            rec = coll.setdefault(
                k, {"count": 0, "operand_bytes": 0.0, "wire_bytes": 0.0}
            )
            for kk in rec:
                rec[kk] += v[kk] * mult[c.name]
    return {
        "flops": total_flops,
        "bytes": total_bytes,
        "flops_flat": flat_flops,
        "bytes_flat": flat_bytes,
        "loop_bytes_factor": total_bytes / flat_bytes if flat_bytes else 1.0,
        "wire_bytes_per_chip": total_wire,
        "per_kind": coll,
        "n_computations": len(comps),
    }
