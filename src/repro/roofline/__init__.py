"""roofline subpackage."""
