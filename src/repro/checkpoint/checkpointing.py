"""Fault-tolerant checkpointing: atomic, sharded, elastic.

Layout:  <dir>/step_<N>/
             manifest.json          # tree structure, shapes, dtypes, step
             <leaf-path>.npy        # one file per leaf (per-host shard in
                                    # multi-host deployments)
         <dir>/LATEST               # atomically updated pointer

Guarantees used by the trainer's restart path:
* writes go to ``step_<N>.tmp`` and are renamed only after fsync — a
  failure mid-save never corrupts the previous checkpoint;
* ``restore_latest`` falls back to the newest complete checkpoint;
* restore re-shards to whatever mesh the restoring job uses (elastic
  scaling: the manifest stores *global* arrays; device placement comes
  from the target sharding tree, so 256-chip checkpoints load on 512
  chips and vice versa);
* the data pipeline is stateless (step -> batch), so restart is exact.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "restore", "restore_latest", "latest_step", "list_steps"]


def _flatten(tree, prefix=""):
    """Flatten a dict/list tree to {'a/b/0': leaf} path keys."""
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Atomic save.  Returns the final checkpoint path."""
    flat = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}}
    for name, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fn = name.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][name] = {
            "file": fn,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # atomic LATEST pointer
    ptr_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(ptr_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def list_steps(ckpt_dir: str):
    """Sorted step numbers of every *complete* checkpoint in the dir.

    A checkpoint counts only once its ``manifest.json`` exists — i.e.
    after the atomic tmp-dir rename — so an interrupted save is
    invisible here.

    Args:
        ckpt_dir: Checkpoint root directory.

    Returns:
        Sorted list of int steps (empty if the dir doesn't exist).
    """
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                out.append(int(d[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest complete checkpoint step, or None if there is none.

    Prefers the atomically-updated LATEST pointer, but validates it
    against the complete checkpoints on disk (a pointer written just
    before a crash may name a checkpoint that never finished) —
    falling back to the newest complete step.

    Args:
        ckpt_dir: Checkpoint root directory.

    Returns:
        The step number to resume from, or None for a cold start.

    Example:
        >>> import tempfile
        >>> d = tempfile.mkdtemp()
        >>> latest_step(d) is None
        True
        >>> _ = save(d, 3, {"w": np.zeros(2)})
        >>> _ = save(d, 7, {"w": np.ones(2)})
        >>> latest_step(d)
        7
    """
    steps = list_steps(ckpt_dir)
    if not steps:
        return None
    ptr = os.path.join(ckpt_dir, "LATEST")
    if os.path.exists(ptr):
        try:
            s = int(open(ptr).read().strip())
            if s in steps:
                return s
        except ValueError:
            pass
    return steps[-1]


def restore(ckpt_dir: str, step: int, proto: Any, shardings: Any = None) -> Any:
    """Load checkpoint ``step`` shaped like ``proto``; if ``shardings``
    (a matching tree of jax.sharding.Sharding) is given, leaves are
    placed with jax.device_put — this is the elastic re-shard path."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(path, "manifest.json")))
    flat_proto = _flatten(proto)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out = {}
    for name, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(path, meta["file"]))
        if name in flat_proto:
            want = flat_proto[name]
            arr = arr.astype(want.dtype) if hasattr(want, "dtype") else arr
        if name in flat_shard and flat_shard[name] is not None:
            out[name] = jax.device_put(arr, flat_shard[name])
        else:
            out[name] = jnp.asarray(arr)
    # remap to nested structure using proto as template
    def rebuild(proto, prefix=""):
        """Rebuild the nested tree from the flat ``out`` dict."""
        if isinstance(proto, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in proto.items()}
        if isinstance(proto, (tuple, list)):
            vals = [rebuild(v, f"{prefix}{i}/") for i, v in enumerate(proto)]
            return type(proto)(vals)
        return out[prefix[:-1]]

    return rebuild(proto)


def restore_latest(ckpt_dir: str, proto: Any, shardings: Any = None):
    """Restore the newest complete checkpoint, or signal a cold start.

    Args:
        ckpt_dir: Checkpoint root directory.
        proto: Tree of leaves (or ShapeDtypeStructs) shaping the result.
        shardings: Optional matching tree of ``jax.sharding.Sharding``
            for elastic re-placement.

    Returns:
        ``(tree, step)`` of the newest complete checkpoint, or
        ``(None, None)`` when no checkpoint exists.
    """
    s = latest_step(ckpt_dir)
    if s is None:
        return None, None
    return restore(ckpt_dir, s, proto, shardings), s
