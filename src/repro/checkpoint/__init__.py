"""checkpoint subpackage."""
