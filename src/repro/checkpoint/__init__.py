"""Atomic, sharded, elastic checkpointing (see ``checkpointing``)."""
