"""``simplexlint`` — static verification of kernels and schedules.

The repo's correctness-tooling layer (DESIGN.md §9): a pass registry
(``analysis/registry.py``) whose AST/policy passes enforce source-tree
contracts (the ``pallas_call`` front door, no hardcoded
``interpret=True``, warn-and-delegate shims, resolvable DESIGN.md
§-xrefs, 8x128-aligned tile constants) and whose semantic passes replay
schedule step lists and BlockSpec index maps symbolically — write-race
detection, bijectivity/out-of-bounds verification for every registered
schedule kind (shard views included), and halo-stencil conformance for
every registered kernel body.  No Pallas launch anywhere.

Consumers: ``scripts/simplexlint.py`` (CLI; ``--fix``, ``--json``),
``tests/test_simplexlint.py`` (the tier-1 pytest bridge), and the CI
workflow's ``simplexlint`` step.

Example:
    >>> from repro.analysis import registered_passes
    >>> sorted(p in registered_passes() for p in
    ...        ("write-race", "schedule-bijectivity", "halo-conformance"))
    [True, True, True]
"""

from . import ast_passes, halo_passes, schedule_passes  # noqa: F401 (self-registration)
from .registry import (
    Finding,
    LintContext,
    Pass,
    findings_to_json,
    get_pass,
    register_pass,
    registered_passes,
    run_passes,
)

__all__ = [
    "Finding",
    "LintContext",
    "Pass",
    "findings_to_json",
    "get_pass",
    "register_pass",
    "registered_passes",
    "run_passes",
]
