"""Halo-conformance pass: declared stencils vs blocks actually touched.

A ``KernelBody`` *declares* the 3^m block-offset stencil its per-tile
compute reads (``KernelBody.stencil`` — full neighborhood for ``halo``
bodies, centre-only otherwise); the engine *fetches* one shifted input
ref per offset in ``kernels.engine.launch_shifts`` and builds each
ref's ``BlockSpec`` index map from
``kernels.engine.shift_block_transform``.  This pass diffs the two and
then replays every fetch map over real schedule walks (DESIGN.md §9):

* an offset the engine fetches but the body does not declare is an
  **undeclared halo read** — the compute can observe blocks the
  contract says it never touches;
* a declared offset the engine never fetches is a **stale declaration**
  — the compute would read unassembled (zero) neighbours;
* for every fetched offset, the evaluated index map must equal the
  boundary-correct neighbour (wrap mod nb under ``'periodic'``, clip +
  trash-park under ``'free'``) and stay inside ``[0, nb]`` — the range
  Pallas can actually address after trash-tile padding.

All checks replay index maps with numpy step enumerations — no Pallas
launch.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from .registry import Finding, LintContext, register_pass
from .schedule_passes import eval_schedule_map

__all__ = [
    "HALO_MN",
    "check_body_halo",
]

# (m, nb, kind) combos the registered pass replays per body: a pow2
# multi-axis walk, the bounding-box walk (invalid steps exercise the
# trash parking), and a non-pow2 composite walk at m=3.
HALO_MN: Tuple[Tuple[int, int, str], ...] = (
    (2, 4, "hmap"),
    (2, 4, "bb"),
    (3, 4, "hmap"),
    (3, 4, "bb"),
    (3, 3, "composite"),
)


def check_body_halo(body, m: int, nb: int, kind: str) -> List[Finding]:
    """Verify one body's stencil declaration at one (m, nb, kind).

    Args:
        body: A ``KernelBody`` instance (or registered name).
        m: Simplex dimension.
        nb: Tile count per side.
        kind: Schedule kind to replay the fetch maps over.

    Returns:
        Findings for declaration/fetch mismatches, boundary-handling
        drift, and out-of-range fetches; empty when conformant.
    """
    from repro.core.schedule import SimplexSchedule, resolve_kind
    from repro.kernels.engine import (
        get_body,
        launch_shifts,
        shift_block_transform,
    )

    body = get_body(body)
    where = (
        f"<semantic:body {body.name} m={m} nb={nb} kind={kind}>"
    )
    declared = set(body.stencil(m))
    fetched = set(launch_shifts(body, m))
    out: List[Finding] = []
    for d in sorted(fetched - declared):
        out.append(Finding(
            "halo-conformance", where, 0,
            f"undeclared halo read: engine fetches block offset {d} "
            f"but {body.name}.stencil({m}) does not declare it",
        ))
    for d in sorted(declared - fetched):
        out.append(Finding(
            "halo-conformance", where, 0,
            f"stale stencil declaration: {body.name}.stencil({m}) "
            f"declares offset {d} the engine never fetches (the "
            "compute would read unassembled zeros)",
        ))
    if out:
        return out

    sched = SimplexSchedule(m, nb, resolve_kind(m, nb, kind))
    coords, valid = eval_schedule_map(sched)
    blocks = tuple(c for c in coords[::-1])  # array-axis order
    boundary = body.boundary(m)
    for d in sorted(fetched):
        tr = shift_block_transform(d, nb, boundary)
        got = [
            np.asarray(b).astype(np.int64)
            for b in tr(blocks, coords, valid)
        ]
        if boundary == "periodic":
            want = [
                (blocks[j] + d[j]) % nb for j in range(m)
            ]
        else:
            want = [
                np.clip(blocks[j] + d[j], 0, nb - 1) for j in range(m)
            ]
            want[0] = np.where(valid, want[0], nb)
        for j in range(m):
            bad = np.nonzero(got[j] != want[j])[0]
            if bad.size:
                s = int(bad[0])
                out.append(Finding(
                    "halo-conformance", where, 0,
                    f"fetch map for offset {d} touches block "
                    f"{tuple(int(g[s]) for g in got)} at grid step {s}; "
                    f"the {boundary} boundary rule expects "
                    f"{tuple(int(w[s]) for w in want)}",
                ))
                break
        lo_ok = all((g >= 0).all() for g in got)
        hi_ok = (got[0] <= nb).all() and all(
            (g <= nb - 1).all() for g in got[1:]
        )
        if not (lo_ok and hi_ok):
            out.append(Finding(
                "halo-conformance", where, 0,
                f"fetch map for offset {d} addresses a block outside "
                f"[0, {nb}] — unmapped memory even with the trash row",
            ))
    return out


def _domain_bodies():
    """Registered bodies launched through the generic domain launcher
    (bodies overriding ``launch`` — MAP — have no block stencil)."""
    from repro.kernels.engine import KernelBody, get_body, registered_bodies

    for name in registered_bodies():
        body = get_body(name)
        if type(body).launch is KernelBody.launch:
            yield body


@register_pass(
    "halo-conformance", "semantic",
    "each body's declared stencil matches the blocks its index maps "
    "touch",
)
def _halo_pass(ctx: LintContext,
               combos: Optional[Sequence] = None) -> List[Finding]:
    out: List[Finding] = []
    for body in _domain_bodies():
        for m, nb, kind in (combos or HALO_MN):
            out.extend(check_body_halo(body, m, nb, kind))
    return out
