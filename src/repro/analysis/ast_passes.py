"""AST/policy passes: source-tree contracts of the kernels stack.

Five contracts, each previously enforced ad hoc (two as AST snippets in
``tests/test_compiled.py``, the §-xref audit in ``tests/test_docs_xref``,
the rest only by review) and now first-class registry passes
(DESIGN.md §9):

* ``pallas-front-door`` — ``pl.pallas_call`` is constructed only inside
  ``kernels/engine.py`` (the ``pallas_launch`` front door) and
  ``kernels/compiled.py``; every other module must launch through the
  engine so the execution policy cannot be bypassed.
* ``hardcoded-interpret`` — no call site pins ``interpret=True``; the
  mode must thread through ``kernels/policy.py`` (mechanically fixable
  to ``interpret=None``).
* ``shim-deprecation`` — anything documented as deprecated must
  warn-and-delegate: raise ``DeprecationWarning`` (directly or via a
  module-local helper) and return a delegating call, never reimplement
  or silently alias.
* ``design-xref`` — every ``DESIGN.md §x[.y]`` string in the tree
  resolves to an existing DESIGN.md section header.
* ``tile-alignment`` — module-level tile/block constants satisfy the
  Mosaic 8x128 contract of ``kernels/policy.py`` (ints multiples of the
  sublane; shape tuples accepted by ``tile_alignment_ok``).
"""

from __future__ import annotations

import ast
import pathlib
import re
from typing import List

from .registry import Finding, LintContext, register_pass

__all__ = [
    "PALLAS_ALLOWED",
    "design_sections",
]

# Basenames allowed to construct pl.pallas_call (the front door and the
# fused-XLA module, which owns its own jit programs).
PALLAS_ALLOWED = ("engine.py", "compiled.py")

_SECTION_RE = re.compile(r"^#{2,}\s+(§\d+(?:\.\d+)?)\b", re.MULTILINE)
_XREF_RE = re.compile(r"DESIGN\.md\s+(§\d+(?:\.\d+)?)")
_TILE_NAME_RE = re.compile(r"(?:^|_)(?:TILE|BLOCK)S?(?:_|$)")


def design_sections(repo_root: pathlib.Path) -> set:
    """Section anchors (``§N`` / ``§N.M``) present in DESIGN.md.

    Args:
        repo_root: Directory containing DESIGN.md.

    Returns:
        Set of anchor strings; empty when DESIGN.md is absent.
    """
    path = repo_root / "DESIGN.md"
    if not path.exists():
        return set()
    return set(_SECTION_RE.findall(path.read_text()))


@register_pass(
    "pallas-front-door", "ast",
    "pl.pallas_call constructed only in kernels/engine.py+compiled.py",
)
def _pallas_front_door(ctx: LintContext) -> List[Finding]:
    out = []
    for py in ctx.python_sources():
        if py.name in PALLAS_ALLOWED:
            continue
        _, tree = ctx.parsed(py)
        for node in ast.walk(tree):
            hit = (
                isinstance(node, ast.Attribute)
                and node.attr == "pallas_call"
            ) or (isinstance(node, ast.Name) and node.id == "pallas_call")
            if hit:
                out.append(Finding(
                    "pallas-front-door", ctx.rel(py), node.lineno,
                    "pallas_call constructed outside the engine front "
                    "door — route through engine.pallas_launch",
                ))
    return out


def _fix_hardcoded_interpret(ctx: LintContext,
                             findings: List[Finding]) -> int:
    """Rewrite each flagged ``interpret=True`` to ``interpret=None``."""
    by_path = {}
    for f in findings:
        by_path.setdefault(f.path, []).append(f)
    fixed = 0
    for rel, fs in by_path.items():
        path = ctx.repo_root / rel
        lines = path.read_text().splitlines(keepends=True)
        for f in fs:
            i = f.line - 1
            new = re.sub(r"interpret\s*=\s*True", "interpret=None",
                         lines[i])
            if new != lines[i]:
                lines[i] = new
                fixed += 1
        path.write_text("".join(lines))
    return fixed


@register_pass(
    "hardcoded-interpret", "ast",
    "no call site pins interpret=True (policy.py resolves the mode)",
    fix=_fix_hardcoded_interpret,
)
def _hardcoded_interpret(ctx: LintContext) -> List[Finding]:
    out = []
    for py in ctx.python_sources():
        _, tree = ctx.parsed(py)
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            for kw in node.keywords:
                if (
                    kw.arg == "interpret"
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True
                ):
                    out.append(Finding(
                        "hardcoded-interpret", ctx.rel(py), node.lineno,
                        "hardcodes interpret=True — pass interpret=None "
                        "and let kernels/policy.py resolve the backend",
                        fixable=True,
                    ))
    return out


def _warns_deprecation(fn: ast.AST, helpers: set) -> bool:
    """True when the function body raises DeprecationWarning (directly
    via ``warnings.warn`` or through a module-local warn helper)."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        callee = node.func
        name = (
            callee.attr if isinstance(callee, ast.Attribute)
            else callee.id if isinstance(callee, ast.Name) else None
        )
        if name in helpers:
            return True
        if name == "warn":
            names = {
                n.id for n in ast.walk(node) if isinstance(n, ast.Name)
            }
            if "DeprecationWarning" in names:
                return True
    return False


def _delegates(fn: ast.AST) -> bool:
    """True when the body returns (or tail-calls) a delegating call."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and isinstance(
            node.value, ast.Call
        ):
            return True
    return False


@register_pass(
    "shim-deprecation", "ast",
    "deprecated entry points must warn (DeprecationWarning) and delegate",
)
def _shim_deprecation(ctx: LintContext) -> List[Finding]:
    out = []
    for py in ctx.python_sources():
        _, tree = ctx.parsed(py)
        # module-local helpers that themselves raise DeprecationWarning
        helpers = {
            node.name
            for node in tree.body
            if isinstance(node, ast.FunctionDef)
            and _warns_deprecation(node, set())
        }
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                doc = ast.get_docstring(node) or ""
                if "deprecated" not in doc.lower():
                    continue
                inits = [
                    n for n in node.body
                    if isinstance(n, ast.FunctionDef)
                    and n.name == "__init__"
                ]
                if not inits or not _warns_deprecation(inits[0], helpers):
                    out.append(Finding(
                        "shim-deprecation", ctx.rel(py), node.lineno,
                        f"deprecated class {node.name!r} must emit a "
                        "DeprecationWarning in __init__",
                    ))
            elif isinstance(node, ast.FunctionDef):
                doc = ast.get_docstring(node) or ""
                if not doc.lower().startswith("deprecated"):
                    continue
                if not _warns_deprecation(node, helpers):
                    out.append(Finding(
                        "shim-deprecation", ctx.rel(py), node.lineno,
                        f"deprecated shim {node.name!r} must emit a "
                        "DeprecationWarning before delegating",
                    ))
                elif not _delegates(node):
                    out.append(Finding(
                        "shim-deprecation", ctx.rel(py), node.lineno,
                        f"deprecated shim {node.name!r} must delegate "
                        "(return the replacement's result), not "
                        "reimplement",
                    ))
    return out


@register_pass(
    "design-xref", "ast",
    "every 'DESIGN.md §x' cross-reference resolves to a real section",
)
def _design_xref(ctx: LintContext) -> List[Finding]:
    secs = design_sections(ctx.repo_root)
    out = []
    targets = list(ctx.python_sources())
    for extra in ("scripts", "benchmarks", "examples", "tests"):
        root = ctx.repo_root / extra
        if root.exists() and not ctx.src_root.is_relative_to(root):
            targets.extend(
                p for p in sorted(root.rglob("*.py"))
                # fixtures_lint holds intentionally-stale references that
                # the fixture tests feed back through this pass.
                if "fixtures_lint" not in p.parts
            )
    readme = ctx.repo_root / "README.md"
    texts = [(p, p.read_text()) for p in targets]
    if readme.exists():
        texts.append((readme, readme.read_text()))
    for path, text in texts:
        for i, line in enumerate(text.splitlines(), start=1):
            for ref in _XREF_RE.findall(line):
                if ref not in secs:
                    out.append(Finding(
                        "design-xref", ctx.rel(path), i,
                        f"stale cross-reference DESIGN.md {ref} "
                        f"(existing sections: {sorted(secs)})",
                    ))
    return out


def _const_ints(node: ast.AST):
    """Int literals of a constant int/tuple/list assignment (else None)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return [node.value], False
    if isinstance(node, (ast.Tuple, ast.List)):
        vals = []
        for elt in node.elts:
            if not (
                isinstance(elt, ast.Constant)
                and isinstance(elt.value, int)
                and not isinstance(elt.value, bool)
            ):
                return None
            vals.append(elt.value)
        return vals, True
    return None


@register_pass(
    "tile-alignment", "ast",
    "module-level tile/block constants satisfy the 8x128 contract",
)
def _tile_alignment(ctx: LintContext) -> List[Finding]:
    from repro.kernels.policy import TPU_SUBLANE, tile_alignment_ok

    out = []
    for py in ctx.python_sources():
        _, tree = ctx.parsed(py)
        for node in tree.body:
            if not isinstance(node, ast.Assign):
                continue
            targets = [
                t.id for t in node.targets if isinstance(t, ast.Name)
            ]
            name = next(
                (t for t in targets
                 if t.isupper() and _TILE_NAME_RE.search(t)),
                None,
            )
            if name is None:
                continue
            parsed = _const_ints(node.value)
            if parsed is None:
                continue
            vals, is_seq = parsed
            if is_seq and ("SHAPE" in name or "TILE" in name) \
                    and len(vals) >= 2:
                if not tile_alignment_ok(vals):
                    out.append(Finding(
                        "tile-alignment", ctx.rel(py), node.lineno,
                        f"{name} = {tuple(vals)} violates the compiled "
                        "8x128 block-shape contract "
                        "(kernels/policy.check_tile_alignment)",
                    ))
                continue
            for v in vals:
                if v % TPU_SUBLANE != 0:
                    out.append(Finding(
                        "tile-alignment", ctx.rel(py), node.lineno,
                        f"{name} contains {v}, not a multiple of the "
                        f"{TPU_SUBLANE}-row sublane (kernels/policy.py)",
                    ))
    return out
