"""Command-line front end of ``simplexlint`` (DESIGN.md §9).

``scripts/simplexlint.py`` delegates here.  Modes:

* default — human-readable findings, one per line, exit 1 on any;
* ``--json`` — the stable CI report (``findings_to_json`` schema);
* ``--fix`` — apply mechanical fixers (e.g. ``interpret=True`` ->
  ``interpret=None``) then re-run, reporting only what remains;
* ``--passes a,b`` / ``--list`` — subset selection and discovery.
"""

from __future__ import annotations

import argparse
import pathlib
import sys
from typing import Optional, Sequence

from .registry import (
    findings_to_json,
    get_pass,
    registered_passes,
    run_passes,
)

__all__ = ["main"]


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Run the pass registry and report findings.

    Args:
        argv: CLI arguments (default ``sys.argv[1:]``).

    Returns:
        Process exit code: 0 when every pass is clean, 1 otherwise.
    """
    ap = argparse.ArgumentParser(
        prog="simplexlint",
        description="static verifier for Pallas kernels and simplex "
        "schedules (DESIGN.md §9)",
    )
    ap.add_argument(
        "--root", default=None,
        help="repository root (default: auto-detect from this file)",
    )
    ap.add_argument(
        "--passes", default=None,
        help="comma-separated pass subset (default: all)",
    )
    ap.add_argument(
        "--json", action="store_true",
        help="emit the CI JSON report instead of text findings",
    )
    ap.add_argument(
        "--fix", action="store_true",
        help="apply mechanical fixers, then report what remains",
    )
    ap.add_argument(
        "--list", action="store_true", dest="list_passes",
        help="list registered passes and exit",
    )
    args = ap.parse_args(argv)

    if args.root is not None:
        root = pathlib.Path(args.root).resolve()
    else:
        root = pathlib.Path(__file__).resolve().parents[3]
        if root.name == "src":
            root = root.parent

    names = (
        [p.strip() for p in args.passes.split(",") if p.strip()]
        if args.passes else list(registered_passes())
    )
    unknown = [n for n in names if n not in registered_passes()]
    if unknown:
        print(
            f"simplexlint: unknown pass(es) {unknown}; registered: "
            f"{', '.join(registered_passes())}",
            file=sys.stderr,
        )
        return 2
    if args.list_passes:
        for name in names:
            p = get_pass(name)
            fixable = " [fixable]" if p.fix is not None else ""
            print(f"{name:22s} {p.family:8s} {p.description}{fixable}")
        return 0

    findings = run_passes(root, passes=names, fix=args.fix)
    if args.json:
        print(findings_to_json(findings, names))
    else:
        for f in findings:
            print(f.format())
        print(
            f"simplexlint: {len(findings)} finding(s) from "
            f"{len(names)} pass(es)"
        )
    return 1 if findings else 0


if __name__ == "__main__":  # pragma: no cover - exercised via scripts/
    sys.exit(main())
