"""Semantic schedule passes: race + bijectivity, no Pallas launch.

The correctness story of the block-space map H (PAPER.md §4) reduces to
two schedule-level facts the engine otherwise only observes at runtime:

* **bijectivity** — the valid steps of a walk cover the blocked simplex
  exactly once each (no hole, no duplicate) with every coordinate in
  range; and
* **write-race freedom** — after the engine's output transform (clip +
  trash-tile parking, ``kernels.engine.out_block_transform``) no two
  grid steps write the same live output block, and every invalid step
  parks at the trash row.

Both are decidable by replaying ``SimplexSchedule.map`` over the full
step enumeration (``core.schedule.step_grid_indices``) on small (m, n)
grids — numpy arrays in, no kernel launch.  The registered passes run
the ``DEFAULT_MN`` matrix over every registered kind (kernel-facing
resolution included, so non-pow2 requests verify the ``composite``
walk they actually launch) plus the k-way ``shard`` views of
``distributed.simplex_sharding`` (DESIGN.md §7, §9).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .registry import Finding, LintContext, register_pass

__all__ = [
    "DEFAULT_MN",
    "SHARD_COUNTS",
    "eval_schedule_map",
    "check_schedule_bijectivity",
    "check_schedule_race",
    "verified_schedules",
]

# (pow2 n, non-pow2 n) verified per dimension — every registered kind
# at every m is checked at both, through kernel-facing kind resolution.
DEFAULT_MN: Dict[int, Tuple[int, int]] = {2: (8, 6), 3: (8, 6), 4: (4, 6)}

# k values for the shard-view verification at each (m, n).
SHARD_COUNTS: Tuple[int, ...] = (2, 3)


def eval_schedule_map(sched) -> Tuple[List[np.ndarray], np.ndarray]:
    """Replay a schedule's map over its full step enumeration.

    Args:
        sched: Any schedule surface (``.grid``/``.steps``/``.map``/
            ``.prefetch``) — ``SimplexSchedule``, piece, or shard.

    Returns:
        ``(coords, valid)``: m math-order int coordinate arrays and the
        boolean validity flag, one entry per grid step.

    Example:
        >>> from repro.core.schedule import SimplexSchedule
        >>> coords, valid = eval_schedule_map(SimplexSchedule(2, 4, "bb"))
        >>> int(valid.sum())  # tri(4) valid steps in the 4x4 box
        10
    """
    from repro.core.schedule import step_grid_indices

    ws = step_grid_indices(sched)
    pref = getattr(sched, "prefetch", None)
    out = sched.map(*ws, *(() if pref is None else (pref,)))
    coords = [np.asarray(c).astype(np.int64) for c in out[:-1]]
    valid = np.asarray(out[-1]).astype(bool)
    return coords, valid


def _domain_set(m: int, n: int) -> set:
    """All in-domain blocks: m=2 inclusive lower triangle, else sum<n."""
    if m == 2:
        return {(x, y) for y in range(n) for x in range(y + 1)}
    from repro.core.simplex import enumerate_simplex

    return set(map(tuple, enumerate_simplex(n, m)))


def _label(sched, m: int, n: int) -> str:
    kind = getattr(sched, "kind", "?")
    return f"<semantic:schedule m={m} n={n} kind={kind}>"


def check_schedule_bijectivity(sched, m: int, n: int,
                               pass_name: str = "schedule-bijectivity",
                               ) -> List[Finding]:
    """Valid steps must hit every domain block exactly once, in range.

    Args:
        sched: The schedule (or shard/piece view) to verify.
        m: Simplex dimension.
        n: Blocked side length the walk covers.
        pass_name: Name stamped on the findings.

    Returns:
        Findings for out-of-bounds coordinates, out-of-domain valid
        steps, duplicate coverage, and uncovered domain blocks.
    """
    coords, valid = eval_schedule_map(sched)
    where = _label(sched, m, n)
    out: List[Finding] = []
    stack = np.stack(coords, axis=1)  # (steps, m), math order
    vstack = stack[valid]
    oob = (vstack < 0) | (vstack >= n)
    if oob.any():
        step = int(np.nonzero(oob.any(axis=1))[0][0])
        out.append(Finding(
            pass_name, where, 0,
            f"out-of-bounds coordinate {tuple(vstack[step])} on a valid "
            f"step (n={n})",
        ))
        return out
    domain = _domain_set(m, n)
    seen: Dict[tuple, int] = {}
    for row in map(tuple, vstack):
        seen[row] = seen.get(row, 0) + 1
    for row, count in seen.items():
        if row not in domain:
            out.append(Finding(
                pass_name, where, 0,
                f"valid step maps outside the simplex domain: {row}",
            ))
        elif count > 1:
            out.append(Finding(
                pass_name, where, 0,
                f"block {row} covered {count} times (walk is not "
                "injective on valid steps)",
            ))
    missing = domain - set(seen)
    if missing:
        out.append(Finding(
            pass_name, where, 0,
            f"{len(missing)} domain blocks never visited, e.g. "
            f"{sorted(missing)[:3]}",
        ))
    return out


def check_schedule_race(sched, m: int, n: int,
                        pass_name: str = "write-race") -> List[Finding]:
    """No two grid steps may write the same live output block.

    Applies the engine's actual output index-map transform
    (``kernels.engine.out_block_transform``: clip to range, park
    invalid steps at the trash row) to every step of the walk, then
    checks (a) valid steps land on pairwise-distinct blocks — two steps
    sharing an output block is the λ-map overlap race, the launch-order-
    dependent write the triangular-map line of work guards against —
    and (b) invalid steps all park at the trash row, never on a live
    block.

    Args:
        sched: The schedule (or shard/piece view) to verify.
        m: Simplex dimension.
        n: Blocked side length (trash row index).
        pass_name: Name stamped on the findings.

    Returns:
        Findings for racing step pairs and mis-parked invalid steps.
    """
    from repro.kernels.engine import out_block_transform

    coords, valid = eval_schedule_map(sched)
    where = _label(sched, m, n)
    blocks = tuple(coords[::-1])  # array-axis order
    out_blocks = out_block_transform(n)(blocks, coords, valid)
    cols = [np.asarray(b).astype(np.int64) for b in out_blocks]
    stack = np.stack(cols, axis=1)  # (steps, m)
    out: List[Finding] = []
    seen: Dict[tuple, int] = {}
    for step, row in enumerate(map(tuple, stack)):
        if valid[step]:
            if row in seen:
                out.append(Finding(
                    pass_name, where, 0,
                    f"write race: grid steps {seen[row]} and {step} both "
                    f"write output block {row}",
                ))
            else:
                seen[row] = step
        elif row[0] != n:
            out.append(Finding(
                pass_name, where, 0,
                f"invalid grid step {step} writes live block {row} "
                f"instead of parking at the trash row {n}",
            ))
    return out


def verified_schedules(m: int, n: int):
    """The schedule views the semantic passes verify at one (m, n).

    Yields every registered kind after kernel-facing resolution
    (``resolve_kind`` — what a launch at this (m, n) actually walks),
    the per-piece views of composite walks, and the k-way
    ``ShardSchedule`` views of the fold partition for each k in
    ``SHARD_COUNTS``.

    Args:
        m: Simplex dimension.
        n: Blocked side length.

    Yields:
        ``(label, views)`` pairs — ``views`` is a list of schedule
        objects whose *union* of valid steps must cover the domain
        bijectively (a single schedule for plain kinds).
    """
    from repro.core.schedule import (
        SimplexSchedule,
        registered_kinds,
        resolve_kind,
    )

    resolved_seen = set()
    for kind in registered_kinds(m):
        resolved = resolve_kind(m, n, kind)
        if resolved in resolved_seen:
            continue
        resolved_seen.add(resolved)
        try:
            sched = SimplexSchedule(m, n, resolved)
        except (ValueError, AssertionError):
            continue
        yield f"{kind}->{resolved}" if resolved != kind else kind, [sched]
        if resolved == "composite":
            yield "composite-pieces", list(sched.split_pieces())

    from repro.distributed.simplex_sharding import shard_schedules

    base = SimplexSchedule(m, n, "table")
    for k in SHARD_COUNTS:
        yield f"shard(k={k})", list(shard_schedules(base, k))


def _union_findings(check, views, m, n) -> List[Finding]:
    """Run ``check`` on the union of several schedule views.

    Single view: delegate.  Multiple views (shards, pieces): each view
    is checked for internal consistency *and* the union must cover the
    domain exactly once — a cross-view duplicate is a race/coverage
    violation even when every view is clean in isolation.
    """
    if len(views) == 1:
        return check(views[0], m, n)
    out: List[Finding] = []
    union = _UnionSchedule(views)
    out.extend(check(union, m, n))
    return out


class _UnionSchedule:
    """Concatenated view of several schedules (shards/pieces) so the
    union walk can be verified with the single-schedule checkers."""

    def __init__(self, views):
        self.views = views
        self.kind = "+".join(
            str(getattr(v, "kind", "?")) for v in views[:1]
        ) + f"[x{len(views)}]"
        self.m = views[0].m
        self.n = views[0].n
        self.prefetch = None
        self.steps = sum(v.steps for v in views)
        self.grid = (self.steps,)

    def map(self, lin):
        """Concatenated evaluation (host-side verification only)."""
        lin = np.asarray(lin)
        coords_cols = None
        valids = []
        chunks = []
        off = 0
        for v in self.views:
            pref = getattr(v, "prefetch", None)
            ws = []
            sub = np.arange(v.steps, dtype=np.int64)
            rem = sub
            for g in v.grid:
                ws.append(rem % g)
                rem = rem // g
            out = v.map(*ws, *(() if pref is None else (pref,)))
            chunks.append([np.asarray(c) for c in out[:-1]])
            valids.append(np.asarray(out[-1]).astype(bool))
            off += v.steps
        m = len(chunks[0])
        coords_cols = [
            np.concatenate([c[j] for c in chunks]) for j in range(m)
        ]
        valid = np.concatenate(valids)
        return tuple(coords_cols) + (valid,)


def _run_matrix(check, pass_name: str,
                mn: Optional[Dict[int, Sequence[int]]] = None,
                ) -> List[Finding]:
    out: List[Finding] = []
    for m, ns in (mn or DEFAULT_MN).items():
        for n in ns:
            for label, views in verified_schedules(m, n):
                out.extend(_union_findings(check, views, m, n))
    return out


@register_pass(
    "schedule-bijectivity", "semantic",
    "every registered kind's valid steps cover the simplex exactly once",
)
def _bijectivity_pass(ctx: LintContext) -> List[Finding]:
    return _run_matrix(check_schedule_bijectivity, "schedule-bijectivity")


@register_pass(
    "write-race", "semantic",
    "no two grid steps write the same live output block (engine "
    "out-transform applied)",
)
def _race_pass(ctx: LintContext) -> List[Finding]:
    return _run_matrix(check_schedule_race, "write-race")
