"""Pass registry and finding model of ``simplexlint`` (DESIGN.md §9).

The static-analysis subsystem is a flat registry of *passes*.  A pass is
a named callable ``run(ctx) -> list[Finding]`` over a ``LintContext``
(repo root + parsed-source cache); mechanical passes may also carry a
``fix(ctx, findings) -> int`` hook that rewrites sources in place.
Passes register themselves at import time via ``register_pass`` — the
CLI (``scripts/simplexlint.py``), the pytest bridge
(``tests/test_simplexlint.py``) and CI all run the same registry, so a
new pass is inherited by every consumer for free.

Two pass families ship (DESIGN.md §9):

* **AST/policy passes** (``analysis/ast_passes.py``) — source-tree
  contracts: the ``pallas_call`` front door, no hardcoded
  ``interpret=True``, warn-and-delegate deprecation shims, resolvable
  DESIGN.md §-xrefs, 8x128-aligned tile constants.
* **Semantic passes** (``analysis/schedule_passes.py``,
  ``analysis/halo_passes.py``) — schedule step lists and BlockSpec
  index maps replayed symbolically over small (m, n) grids, no Pallas
  launch: write-race detection, bijectivity/out-of-bounds, and
  halo-stencil conformance.
"""

from __future__ import annotations

import json
import pathlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Finding",
    "LintContext",
    "Pass",
    "register_pass",
    "registered_passes",
    "get_pass",
    "run_passes",
    "findings_to_json",
]


@dataclass(frozen=True)
class Finding:
    """One verified violation a pass reports.

    Attributes:
        pass_name: Name of the reporting pass.
        path: Repo-relative file path, or a ``<semantic:...>`` locator
            for schedule/kernel findings with no single source line.
        line: 1-based source line (0 for semantic findings).
        message: Human-readable statement of the violation.
        fixable: True when the owning pass can rewrite it mechanically.
    """

    pass_name: str
    path: str
    line: int
    message: str
    fixable: bool = False

    def format(self) -> str:
        """``path:line: [pass] message`` — the CLI's text row."""
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: [{self.pass_name}] {self.message}"


@dataclass
class LintContext:
    """Everything a pass may inspect (filled once per run).

    Attributes:
        repo_root: Repository root (DESIGN.md, scripts/, benchmarks/).
        src_root: Python tree the AST passes scan (``src/repro``).
        cache: Per-run scratch shared between passes (parsed ASTs).
    """

    repo_root: pathlib.Path
    src_root: pathlib.Path
    cache: Dict[str, object] = field(default_factory=dict)

    def python_sources(self) -> List[pathlib.Path]:
        """Sorted ``*.py`` files under ``src_root`` (cached per run)."""
        if "py_sources" not in self.cache:
            self.cache["py_sources"] = sorted(self.src_root.rglob("*.py"))
        return self.cache["py_sources"]

    def parsed(self, path: pathlib.Path):
        """The (source text, ast.Module) pair of ``path`` (cached)."""
        import ast

        key = f"ast:{path}"
        if key not in self.cache:
            text = path.read_text()
            self.cache[key] = (text, ast.parse(text))
        return self.cache[key]

    def rel(self, path: pathlib.Path) -> str:
        """``path`` relative to the repo root, as a forward-slash str."""
        try:
            return path.relative_to(self.repo_root).as_posix()
        except ValueError:
            return str(path)


@dataclass(frozen=True)
class Pass:
    """A registered analysis pass.

    Attributes:
        name: Registry key (kebab-case, e.g. ``"write-race"``).
        family: ``'ast'`` (source contracts) or ``'semantic'``
            (schedule/kernel evaluation).
        run: ``run(ctx) -> list[Finding]``.
        description: One-line summary shown by ``--list``.
        fix: Optional mechanical rewriter
            ``fix(ctx, findings) -> fixed_count``.
    """

    name: str
    family: str
    run: Callable[[LintContext], List["Finding"]]
    description: str
    fix: Optional[Callable[[LintContext, List["Finding"]], int]] = None


_PASSES: Dict[str, Pass] = {}


def register_pass(name: str, family: str, description: str,
                  fix: Optional[Callable] = None):
    """Register an analysis pass under ``name``.

    Args:
        name: Unique pass name.
        family: ``'ast'`` or ``'semantic'``.
        description: One-line summary.
        fix: Optional mechanical fixer hook.

    Returns:
        A decorator recording ``run(ctx) -> list[Finding]`` and
        returning it unchanged.  Usage::

            @register_pass("my-pass", "ast", "what it checks")
            def _run(ctx): ...

    Example:
        >>> import repro.analysis  # passes self-register on import
        >>> "write-race" in registered_passes()
        True
    """
    if family not in ("ast", "semantic"):
        raise ValueError(f"unknown pass family {family!r}")

    def _deco(run):
        _PASSES[name] = Pass(
            name=name, family=family, run=run,
            description=description, fix=fix,
        )
        return run

    return _deco


def registered_passes() -> Tuple[str, ...]:
    """Sorted names of every registered pass."""
    return tuple(sorted(_PASSES))


def get_pass(name: str) -> Pass:
    """Resolve a pass by name (``ValueError`` on unknown names)."""
    if name not in _PASSES:
        raise ValueError(
            f"no pass named {name!r}; registered: {registered_passes()}"
        )
    return _PASSES[name]


def run_passes(
    repo_root, src_root=None, passes: Optional[Sequence[str]] = None,
    fix: bool = False,
) -> List[Finding]:
    """Run (a subset of) the registry and return surviving findings.

    Args:
        repo_root: Repository root directory.
        src_root: Python tree for AST passes; defaults to
            ``repo_root / "src" / "repro"``.
        passes: Pass names to run (default: all, sorted).
        fix: Apply each pass's mechanical fixer to its fixable
            findings, then re-run that pass; only unfixed findings are
            returned.

    Returns:
        All findings, in registry order.
    """
    repo_root = pathlib.Path(repo_root).resolve()
    if src_root is None:
        src_root = repo_root / "src" / "repro"
    names = list(passes) if passes is not None else list(registered_passes())
    out: List[Finding] = []
    for name in names:
        p = get_pass(name)
        ctx = LintContext(repo_root=repo_root,
                          src_root=pathlib.Path(src_root))
        found = p.run(ctx)
        if fix and p.fix is not None and any(f.fixable for f in found):
            p.fix(ctx, [f for f in found if f.fixable])
            ctx = LintContext(repo_root=repo_root,
                              src_root=pathlib.Path(src_root))
            found = p.run(ctx)
        out.extend(found)
    return out


def findings_to_json(findings: Sequence[Finding],
                     passes: Sequence[str]) -> str:
    """The CI-facing JSON report (stable schema, version 1).

    Args:
        findings: Findings to serialize.
        passes: Names of the passes that ran.

    Returns:
        A JSON document with ``version``/``passes``/``counts``/
        ``findings`` keys; ``findings`` rows mirror the ``Finding``
        dataclass.
    """
    counts: Dict[str, int] = {}
    for f in findings:
        counts[f.pass_name] = counts.get(f.pass_name, 0) + 1
    return json.dumps(
        {
            "version": 1,
            "passes": list(passes),
            "counts": counts,
            "findings": [
                {
                    "pass": f.pass_name,
                    "path": f.path,
                    "line": f.line,
                    "message": f.message,
                    "fixable": f.fixable,
                }
                for f in findings
            ],
        },
        indent=2,
    )
