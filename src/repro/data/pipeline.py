"""Deterministic synthetic LM data pipeline.

Stateless: ``batch = f(seed, step)`` — a restart at step k reproduces
exactly the batch stream a continuous run would have seen, which is what
makes checkpoint/restart bit-exact (fault tolerance without data-loader
state).  Per-host sharding slices the global batch by data-axis index so
each host materializes only its shard (the pattern a real multi-host
loader uses; in this single-process container the full batch is built
and GSPMD shards it).

The token stream is a mixture of Zipf-distributed unigrams and local
n-gram structure so losses move meaningfully during the example runs
(pure uniform tokens give a constant-entropy floor).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLM", "host_shard"]


class SyntheticLM:
    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, structured: bool = True):
        self.vocab = vocab
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        self.structured = structured
        # Zipf weights over a capped alphabet for speed
        self._alpha = min(vocab, 4096)
        w = 1.0 / np.arange(1, self._alpha + 1) ** 1.1
        self._probs = jnp.asarray(w / w.sum(), jnp.float32)

    def batch_at(self, step: int) -> Dict[str, Any]:
        """(B, S+1) tokens for train; deterministic in (seed, step)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        toks = jax.random.choice(
            key, self._alpha, (self.batch, self.seq + 1), p=self._probs
        ).astype(jnp.int32)
        if self.structured:
            # inject copy structure: token[t] = token[t-4] on a mask -> a
            # learnable 4-gram dependency
            k2 = jax.random.fold_in(key, 1)
            m = jax.random.uniform(k2, toks.shape) < 0.35
            rolled = jnp.roll(toks, 4, axis=1)
            toks = jnp.where(m, rolled, toks)
        return {"tokens": toks}


def host_shard(batch: Dict[str, Any], host_index: int, n_hosts: int):
    """Slice the global batch for one host (multi-host data loading)."""
    def sl(x):
        per = x.shape[0] // n_hosts
        return x[host_index * per : (host_index + 1) * per]

    return jax.tree_util.tree_map(sl, batch)
