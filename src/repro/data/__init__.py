"""data subpackage."""
