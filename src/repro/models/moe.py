"""Mixture-of-Experts: shared + routed top-k with capacity-based dispatch.

Dispatch is GShard/Switch-style — position-in-expert via a cumulative
sum, capacity-dropped scatter into an (E, C, d) buffer, batched expert
SwiGLU, gather-combine — fully differentiable, no (T, E, C) one-hot
einsum (the scatter/gather forms keep memory at O(T*k*d)).

Distribution (DESIGN.md §4): under ``impl='tp'`` expert ff dims shard
over the 'model' axis via GSPMD like any dense layer; routing/dispatch
runs inside ``shard_map`` over the data axes so capacity is *local* to
each data shard (the GShard "group" semantics real systems use), with a
single per-token psum over 'model' after combine.  ``impl='ep'`` places
whole experts on 'model' shards and exchanges tokens with all-to-all —
the collective-trade alternative measured in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import dense_init, swiglu, swiglu_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg, dtype):
    mc = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, mc.n_experts), jnp.float32, scale=d**-0.5),
        "w1": dense_init(ks[1], (mc.n_experts, d, mc.expert_ff), dtype),
        "w3": dense_init(ks[2], (mc.n_experts, d, mc.expert_ff), dtype),
        "w2": dense_init(ks[3], (mc.n_experts, mc.expert_ff, d), dtype),
    }
    if mc.n_shared:
        shared_ff = mc.shared_ff or mc.n_shared * mc.expert_ff
        p["shared"] = swiglu_init(ks[4], d, shared_ff, dtype)
    return p


def _route(logits, mc):
    """(T, E) router logits -> (gates (T,k), idx (T,k), probs (T,E))."""
    if mc.router == "sigmoid":  # DeepSeek-V3 style
        scores = jax.nn.sigmoid(logits)
        gates, idx = jax.lax.top_k(scores, mc.top_k)
        gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
        probs = scores / (scores.sum(-1, keepdims=True) + 1e-9)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, mc.top_k)
        gates = gates / (gates.sum(-1, keepdims=True) + 1e-9)
    return gates, idx, probs


def _dispatch_compute_combine(x2, gates, idx, probs, p, mc, dt, psum_axis):
    """Local-capacity MoE core.  x2: (T, d)."""
    t, d = x2.shape
    e, k = mc.n_experts, mc.top_k
    cap = int(math.ceil(t * k / e * mc.capacity_factor))
    cap = max(cap, 4)
    # position of each (token, slot) within its expert, GShard priority:
    # slot-major then token order.
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)  # (T, k, E)
    flat = onehot.transpose(1, 0, 2).reshape(k * t, e)
    pos_flat = jnp.cumsum(flat, axis=0) - 1  # (k*T, E)
    pos = (
        jnp.take_along_axis(
            pos_flat.reshape(k, t, e),
            idx.transpose(1, 0)[..., None],
            axis=2,
        )[..., 0]
    ).transpose(1, 0)  # (T, k)
    keep = pos < cap
    slot = jnp.where(keep, idx * cap + pos, e * cap)  # drop -> OOB
    # scatter tokens into the (E*C, d) buffer (duplicated per chosen slot)
    buf = jnp.zeros((e * cap, d), dt)
    xk = jnp.broadcast_to(x2[:, None, :], (t, k, d)).reshape(t * k, d)
    buf = buf.at[slot.reshape(-1)].add(xk, mode="drop")
    buf = buf.reshape(e, cap, d)
    # batched expert SwiGLU
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w3"].astype(dt))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["w2"].astype(dt))
    # gather-combine FIRST, then reduce the (T, d) partial over 'model' —
    # T*d bytes per layer instead of E*C*d (~k*cf x more), see §Perf.
    yf = y.reshape(e * cap, d)
    out_k = jnp.take(yf, jnp.minimum(slot, e * cap - 1).reshape(-1), axis=0)
    out_k = out_k.reshape(t, k, d) * (gates * keep).astype(dt)[..., None]
    out = out_k.sum(1)
    if psum_axis is not None:
        out = jax.lax.psum(out, psum_axis)
    # aux load-balance loss (Switch): E * sum_e f_e * p_e
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(idx, e, dtype=jnp.float32) * keep[..., None]).sum(1), axis=0
    )
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out, aux


def moe_apply(p, cfg, x, mesh=None):
    """x: (B, S, d) -> (out, aux_loss).  ``mesh``: optional jax Mesh whose
    ('pod','data') axes shard tokens and 'model' shards expert ff."""
    mc = cfg.moe
    b, s, d = x.shape
    dt = x.dtype

    def local(xl, router, w1, w3, w2, psum_axis=None):
        t = xl.shape[0] * xl.shape[1]
        x2 = xl.reshape(t, d)
        logits = jnp.dot(x2.astype(jnp.float32), router)
        gates, idx, probs = _route(logits, mc)
        sub = {"w1": w1, "w3": w3, "w2": w2}
        out, aux = _dispatch_compute_combine(
            x2, gates, idx, probs, sub, mc, dt, psum_axis
        )
        return out.reshape(xl.shape), aux

    if mesh is None:
        out, aux = local(x, p["router"], p["w1"], p["w3"], p["w2"])
    elif (
        (getattr(cfg, "moe_impl", "") or mc.impl) == "ep"
        and getattr(cfg, "tp_size", 16) > 1
        and mc.n_experts % mesh.shape.get("model", 1) == 0
    ):
        out, aux = _moe_ep(p, cfg, x, mesh)
    else:
        import numpy as np
        from jax.experimental.shard_map import shard_map

        tp = getattr(cfg, "tp_size", 16) > 1
        dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        if not tp:
            dp = dp + ("model",)
        dp_size = int(np.prod([mesh.shape[a] for a in dp]))
        bspec = dp if b % dp_size == 0 else None  # batch-1 decode: replicate
        ff_ok = tp and mc.expert_ff % mesh.shape["model"] == 0
        ffspec = "model" if ff_ok else None
        psum_ax = "model" if ff_ok else None

        def body(xl, r, w1, w3, w2):
            o, a = local(xl, r, w1, w3, w2, psum_ax)
            return o, jax.lax.pmean(a, dp)

        f = shard_map(
            body,
            mesh=mesh,
            in_specs=(
                P(bspec, None, None),
                P(None, None),
                P(None, None, ffspec),
                P(None, None, ffspec),
                P(None, ffspec, None),
            ),
            out_specs=(P(bspec, None, None), P()),
            check_rep=False,
        )
        out, aux = f(x, p["router"], p["w1"], p["w3"], p["w2"])

    if mc.n_shared:
        out = out + swiglu(p["shared"], x)
    return out, aux * mc.aux_loss_weight


def _moe_ep(p, cfg, x, mesh):
    """Expert parallelism: experts live on 'model' shards; tokens move to
    their experts with all-to-all and return after compute (GShard).

    vs TP-experts: every device computes only E/|model| experts, so the
    expert-weight HBM/gather traffic divides by |model| (the MoE lever of
    EXPERIMENTS.md §Perf C-series); the price is two all-to-alls of
    ~top_k*tokens*d per layer instead of one token psum.
    """
    import numpy as np
    from jax.experimental.shard_map import shard_map

    mc = cfg.moe
    b, s, d = x.shape
    dt = x.dtype
    msize = mesh.shape["model"]
    e_loc = mc.n_experts // msize
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    bspec = dp if b % dp_size == 0 else None

    def body(xl, router, w1, w3, w2):
        # xl: (B_loc, S, d) — replicated over 'model' (bspec covers dp only)
        t = xl.shape[0] * xl.shape[1]
        x2 = xl.reshape(t, d)
        logits = jnp.dot(x2.astype(jnp.float32), router)
        gates, idx, probs = _route(logits, mc)
        # capacity per expert for THIS shard's tokens
        cap = max(int(math.ceil(t * mc.top_k / mc.n_experts
                                * mc.capacity_factor)), 4)
        onehot = jax.nn.one_hot(idx, mc.n_experts, dtype=jnp.int32)
        flat = onehot.transpose(1, 0, 2).reshape(mc.top_k * t, mc.n_experts)
        pos_flat = jnp.cumsum(flat, axis=0) - 1
        pos = jnp.take_along_axis(
            pos_flat.reshape(mc.top_k, t, mc.n_experts),
            idx.transpose(1, 0)[..., None], axis=2,
        )[..., 0].transpose(1, 0)
        keep = pos < cap
        slot = jnp.where(keep, idx * cap + pos, mc.n_experts * cap)
        buf = jnp.zeros((mc.n_experts * cap, d), dt)
        xk = jnp.broadcast_to(x2[:, None, :], (t, mc.top_k, d)).reshape(-1, d)
        buf = buf.at[slot.reshape(-1)].add(xk, mode="drop")
        # (E, cap, d) -> exchange: each model shard keeps its E/msize
        # experts' buffers from EVERY model shard.
        buf = buf.reshape(msize, e_loc, cap, d)
        recv = jax.lax.all_to_all(
            buf, "model", split_axis=0, concat_axis=0, tiled=False
        )  # (msize peers, e_loc, cap, d): peer j's tokens for my experts
        recv = recv.transpose(1, 0, 2, 3).reshape(e_loc, msize * cap, d)
        h = jnp.einsum("ecd,edf->ecf", recv, w1.astype(dt))
        u = jnp.einsum("ecd,edf->ecf", recv, w3.astype(dt))
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, w2.astype(dt))
        y = y.reshape(e_loc, msize, cap, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(
            y, "model", split_axis=0, concat_axis=0, tiled=False
        ).reshape(mc.n_experts * cap, d)  # my tokens' results, expert-major
        out_k = jnp.take(back, jnp.minimum(slot, mc.n_experts * cap - 1)
                         .reshape(-1), axis=0)
        out_k = out_k.reshape(t, mc.top_k, d) * (gates * keep).astype(dt)[..., None]
        out = out_k.sum(1).reshape(xl.shape)
        frac_tokens = jnp.mean(
            (onehot.astype(jnp.float32) * keep[..., None]).sum(1), axis=0
        )
        aux = mc.n_experts * jnp.sum(frac_tokens * jnp.mean(probs, axis=0))
        return out, jax.lax.pmean(aux, dp)

    f = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None),
            P(None, None),
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(P(bspec, None, None), P()),
        check_rep=False,
    )
    return f(x, p["router"], p["w1"], p["w3"], p["w2"])
