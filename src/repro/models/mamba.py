"""Mamba-1 selective SSM block (Jamba's sequence mixer).

Training/prefill uses a parallel associative scan over time (diagonal
A => elementwise first-order recurrence, combine (a, b): (a2*a1,
a2*b1 + b2)); decode is the O(1) per-token recurrence carrying
(ssm state (B, d_inner, d_state), conv tail (B, d_conv-1, d_inner)).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init

__all__ = ["mamba_init", "mamba_apply", "init_mamba_cache"]


def _dims(cfg):
    mc = cfg.mamba
    d_inner = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or math.ceil(cfg.d_model / 16)
    return mc, d_inner, dt_rank


def mamba_init(key, cfg, dtype):
    mc, di, dtr = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    a = jnp.tile(
        jnp.arange(1, mc.d_state + 1, dtype=jnp.float32)[None, :], (di, 1)
    )
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (mc.d_conv, di), dtype, scale=mc.d_conv**-0.5),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * mc.d_state), dtype),
        "dt_proj": dense_init(ks[3], (dtr, di), dtype),
        "dt_bias": jnp.log(
            jnp.exp(
                jnp.clip(
                    jax.random.uniform(ks[4], (di,)) * (0.1 - 0.001) + 0.001,
                    0.0001,
                )
            )
            - 1.0
        ).astype(jnp.float32),
        "a_log": jnp.log(a),  # f32: S4D-real init
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], (di, d), dtype),
    }


def _causal_conv(x, w, b, tail=None):
    """Depthwise causal conv1d.  x: (B, S, di); w: (K, di).

    With ``tail`` (B, K-1, di) the convolution is over [tail; x]
    (decode / chunked prefill); returns (y, new_tail)."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(k))
    new_tail = xp[:, -(k - 1) :]
    return y + b.astype(x.dtype), new_tail


def _ssm_inputs(p, cfg, x_act):
    """x_act: (B, S, di) -> decay (B,S,di,N), u (B,S,di,N), C (B,S,N)."""
    mc, di, dtr = _dims(cfg)
    dt = x_act.dtype
    proj = jnp.dot(x_act, p["x_proj"].astype(dt))
    dt_in, bmat, cmat = jnp.split(proj, [dtr, dtr + mc.d_state], axis=-1)
    delta = jax.nn.softplus(
        jnp.dot(dt_in, p["dt_proj"].astype(dt)).astype(jnp.float32)
        + p["dt_bias"]
    )  # (B,S,di) f32
    a = -jnp.exp(p["a_log"])  # (di, N)
    decay = jnp.exp(delta[..., None] * a)  # (B,S,di,N)
    u = (delta * x_act.astype(jnp.float32))[..., None] * bmat.astype(jnp.float32)[
        :, :, None, :
    ]
    return decay, u, cmat


def mamba_apply(
    p,
    cfg,
    x,
    *,
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    mode: str = "train",
):
    """x: (B, S, d).  Returns (out, new_cache)."""
    mc, di, _ = _dims(cfg)
    dt = x.dtype
    xz = jnp.dot(x, p["in_proj"].astype(dt))
    x_in, z = jnp.split(xz, 2, axis=-1)

    if mode == "decode":
        ssm_state, conv_tail = cache  # (B,di,N) f32, (B,K-1,di)
        xc, new_tail = _causal_conv(x_in, p["conv_w"], p["conv_b"], conv_tail)
        x_act = jax.nn.silu(xc)
        decay, u, cmat = _ssm_inputs(p, cfg, x_act)
        h = decay[:, 0] * ssm_state + u[:, 0]  # (B,di,N)
        y = (h * cmat.astype(jnp.float32)[:, 0, None, :]).sum(-1)  # (B,di)
        y = y + p["d_skip"] * x_act.astype(jnp.float32)[:, 0]
        out = jnp.dot(
            (jax.nn.silu(z[:, 0]).astype(jnp.float32) * y).astype(dt)[:, None],
            p["out_proj"].astype(dt),
        )
        return out, (h, new_tail)

    xc, new_tail = _causal_conv(x_in, p["conv_w"], p["conv_b"])
    x_act = jax.nn.silu(xc)
    decay, u, cmat = _ssm_inputs(p, cfg, x_act)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (decay, u), axis=1)
    y = (h * cmat.astype(jnp.float32)[:, :, None, :]).sum(-1)  # (B,S,di)
    y = y + p["d_skip"] * x_act.astype(jnp.float32)
    out = jnp.dot(
        (jax.nn.silu(z).astype(jnp.float32) * y).astype(dt), p["out_proj"].astype(dt)
    )
    new_cache = None
    if mode == "prefill":
        new_cache = (h[:, -1], new_tail)
    return out, new_cache


def init_mamba_cache(cfg, batch, dtype):
    mc, di, _ = _dims(cfg)
    return (
        jnp.zeros((batch, di, mc.d_state), jnp.float32),
        jnp.zeros((batch, mc.d_conv - 1, di), dtype),
    )
