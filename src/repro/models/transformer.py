"""Block assembly and scanned layer stacks.

A model is: ``n_prefix`` unrolled prefix layers + ``n_periods`` scanned
repetitions of a (possibly heterogeneous) ``period`` of LayerSpecs —
scan keeps the HLO O(period) instead of O(n_layers), which is what makes
the 61-layer/80-layer dry-runs compile quickly and remat cheap.
Encoder-decoder models add a bidirectional encoder stack and per-layer
cross-attention in the decoder.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attn_apply, attn_init, full_attention, init_kv_cache
from .layers import rmsnorm, rmsnorm_init, swiglu, swiglu_init
from .mamba import init_mamba_cache, mamba_apply, mamba_init
from .mla import init_mla_cache, mla_apply, mla_init
from .moe import moe_apply, moe_init
from .xlstm import (
    init_mlstm_cache,
    init_slstm_cache,
    mlstm_apply,
    mlstm_init,
    slstm_apply,
    slstm_init,
)

__all__ = ["block_init", "block_apply", "stack_init", "stack_apply", "init_block_cache"]


def block_init(key, cfg, spec, dtype, cross: bool = False):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": rmsnorm_init(d, dtype)}
    if spec.mixer == "attn":
        if cfg.attention == "mla":
            p["mixer"] = mla_init(ks[0], cfg, dtype)
        else:
            p["mixer"] = attn_init(ks[0], cfg, dtype)
    elif spec.mixer == "mamba":
        p["mixer"] = mamba_init(ks[0], cfg, dtype)
    elif spec.mixer == "mlstm":
        p["mixer"] = mlstm_init(ks[0], cfg, dtype)
    elif spec.mixer == "slstm":
        p["mixer"] = slstm_init(ks[0], cfg, dtype)
    else:
        raise ValueError(spec.mixer)
    if cross:
        p["norm_x"] = rmsnorm_init(d, dtype)
        p["cross"] = attn_init(ks[1], cfg, dtype)
    if spec.ffn == "dense":
        p["norm2"] = rmsnorm_init(d, dtype)
        p["ffn"] = swiglu_init(ks[2], d, cfg.d_ff, dtype)
    elif spec.ffn == "moe":
        p["norm2"] = rmsnorm_init(d, dtype)
        p["ffn"] = moe_init(ks[2], cfg, dtype)
    return p


def block_apply(
    p,
    cfg,
    spec,
    x,
    positions,
    *,
    cache=None,
    mode: str = "train",
    mesh=None,
    enc_out=None,
    cross_cache=None,
    bidirectional: bool = False,
    positions3=None,
):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    mixer_cache = cache.get("mixer") if cache else None
    if spec.mixer == "attn":
        if cfg.attention == "mla":
            o, new_mixer = mla_apply(
                p["mixer"], cfg, h, positions, cache=mixer_cache, mode=mode,
                mesh=mesh,
            )
        else:
            o, new_mixer = attn_apply(
                p["mixer"],
                cfg,
                h,
                positions,
                cache=mixer_cache,
                mode=mode,
                bidirectional=bidirectional,
                positions3=positions3,
                mesh=mesh,
            )
    elif spec.mixer == "mamba":
        o, new_mixer = mamba_apply(p["mixer"], cfg, h, cache=mixer_cache, mode=mode)
    elif spec.mixer == "mlstm":
        o, new_mixer = mlstm_apply(p["mixer"], cfg, h, cache=mixer_cache, mode=mode)
    else:  # slstm
        o, new_mixer = slstm_apply(p["mixer"], cfg, h, cache=mixer_cache, mode=mode)
    x = x + o
    new_cache: Dict[str, Any] = {}
    if new_mixer is not None:
        new_cache["mixer"] = new_mixer

    if "cross" in p and enc_out is not None or cross_cache is not None:
        hx = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        if cross_cache is not None:
            kv = cross_cache
        else:
            # project encoder output once (prefill / train)
            b, sk, _ = enc_out.shape
            hkv, hd = cfg.n_kv_heads, cfg.hd
            dt = x.dtype
            k = jnp.dot(enc_out, p["cross"]["wk"].astype(dt)).reshape(
                b, sk, hkv, hd
            ).transpose(0, 2, 1, 3)
            v = jnp.dot(enc_out, p["cross"]["wv"].astype(dt)).reshape(
                b, sk, hkv, hd
            ).transpose(0, 2, 1, 3)
            kv = (k, v)
            if mode in ("prefill", "decode"):
                new_cache["cross"] = kv
        o, _ = attn_apply(
            p["cross"], cfg, hx, positions, mode=mode, cross_kv=kv
        )
        x = x + o

    if spec.ffn == "dense":
        x = x + swiglu(p["ffn"], rmsnorm(p["norm2"], x, cfg.norm_eps))
    elif spec.ffn == "moe":
        o, aux = moe_apply(p["ffn"], cfg, rmsnorm(p["norm2"], x, cfg.norm_eps), mesh)
        x = x + o
    return x, new_cache, aux


def init_block_cache(cfg, spec, batch, seq, dtype, cross: bool = False):
    c: Dict[str, Any] = {}
    if spec.mixer == "attn":
        if cfg.attention == "mla":
            c["mixer"] = init_mla_cache(cfg, batch, seq, dtype)
        else:
            c["mixer"] = init_kv_cache(cfg, batch, seq, dtype)
    elif spec.mixer == "mamba":
        c["mixer"] = init_mamba_cache(cfg, batch, dtype)
    elif spec.mixer == "mlstm":
        c["mixer"] = init_mlstm_cache(cfg, batch, dtype)
    else:
        c["mixer"] = init_slstm_cache(cfg, batch, dtype)
    if cross:
        hkv, hd = cfg.n_kv_heads, cfg.hd
        c["cross"] = (
            jnp.zeros((batch, hkv, seq, hd), dtype),
            jnp.zeros((batch, hkv, seq, hd), dtype),
        )
    return c


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------


def stack_init(key, cfg, specs, n_periods, dtype, cross: bool = False):
    """Stacked params: each leaf gets a leading (n_periods,) dim."""

    def one(k):
        ks = jax.random.split(k, len(specs))
        return {
            f"l{i}": block_init(ks[i], cfg, s, dtype, cross=cross)
            for i, s in enumerate(specs)
        }

    periods = [one(k) for k in jax.random.split(key, n_periods)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *periods)


def _period_apply(cfg, specs, p, x, positions, caches, mode, mesh, enc_out,
                  bidirectional, positions3):
    new_caches = {}
    aux = jnp.zeros((), jnp.float32)
    for i, spec in enumerate(specs):
        c_i = caches.get(f"l{i}") if caches else None
        cross_cache = c_i.get("cross") if (c_i and mode == "decode") else None
        x, nc, a = block_apply(
            p[f"l{i}"],
            cfg,
            spec,
            x,
            positions,
            cache=c_i,
            mode=mode,
            mesh=mesh,
            enc_out=enc_out,
            cross_cache=cross_cache,
            bidirectional=bidirectional,
            positions3=positions3,
        )
        if mode == "decode" and c_i and "cross" in c_i:
            nc["cross"] = c_i["cross"]  # cross K/V is static during decode
        new_caches[f"l{i}"] = nc
        aux = aux + a
    return x, new_caches, aux


def stack_apply(
    params,
    cfg,
    specs,
    n_periods,
    x,
    positions,
    *,
    caches=None,
    mode: str = "train",
    mesh=None,
    enc_out=None,
    bidirectional: bool = False,
    positions3=None,
):
    """Scan the period stack.  Returns (x, new_caches, aux)."""

    def body(carry, xs):
        x, aux = carry
        p_i, c_i = xs if caches is not None else (xs, None)
        x, nc, a = _period_apply(
            cfg, specs, p_i, x, positions, c_i, mode, mesh, enc_out,
            bidirectional, positions3,
        )
        return (x, aux + a), nc

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    elif cfg.remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )

    xs = (params, caches) if caches is not None else params
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, new_caches, aux
