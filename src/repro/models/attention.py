"""Attention substrate: XLA chunked attention with the paper's folded
simplex schedule, GQA layers, caches, cross- and bidirectional attention.

The folded schedule is the framework's first-class use of the paper's
contribution (DESIGN.md §2): causal attention's (q_tile, kv_tile)
iteration space is a standard 2-simplex; the bounding-box schedule
(``'bb'``) walks the full nq x nq tile grid and masks, spending ~2x the
FLOPs; the folded schedule walks the zero-waste super-orthotope
(nq/2 pairs x nq+1 steps) — HLO dot FLOPs drop by ~2x, visible directly
in the dry-run cost analysis.  On real TPU the same schedule runs as the
Pallas kernel (kernels/flash_attention.py); this module is the portable
XLA realization used by the distributed model.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, rope

NEG_INF = -1e30

__all__ = [
    "chunked_causal_attention",
    "simplex_attention",
    "full_attention",
    "decode_attention",
    "attn_init",
    "attn_apply",
    "init_kv_cache",
]


def _best_chunk(s: int, chunk: int) -> int:
    """Largest divisor of s that is <= chunk (odd tails, e.g. MTP's S-1)."""
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    return chunk


def _gqa_scores(qg, kb):
    """qg: (B, Hkv, G, ..., bq, D), kb: (B, Hkv, ..., bk, D) -> scores f32."""
    return jnp.einsum(
        "bhg...qd,bh...kd->bhg...qk", qg, kb, preferred_element_type=jnp.float32
    )


def _gqa_out(pr, vb):
    return jnp.einsum(
        "bhg...qk,bh...kd->bhg...qd",
        pr.astype(vb.dtype),
        vb,
        preferred_element_type=jnp.float32,
    )


def chunked_causal_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    chunk: int = 512,
    schedule: str = "folded",
    scale: Optional[float] = None,
) -> jax.Array:
    """Causal self-attention, GQA aware, O(S * chunk) live memory.

    q: (B, Hq, S, D); k, v: (B, Hkv, S, D).  schedule:
      'folded' — simplex walk, ~S^2/2 block FLOPs (the paper's map)
      'bb'     — bounding box, S^2 block FLOPs + mask (baseline)
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    dv = v.shape[-1]  # MLA uses v_head_dim != qk head dim
    g = hq // hkv
    if scale is None:
        scale = d**-0.5
    chunk = _best_chunk(s, chunk)
    nq = s // chunk
    if schedule == "folded" and (nq < 2 or nq % 2):
        schedule = "bb"

    qt = q.reshape(b, hkv, g, nq, chunk, d).astype(jnp.float32) * scale
    qt = qt.astype(q.dtype)
    kt = k.reshape(b, hkv, nq, chunk, d)
    vt = v.reshape(b, hkv, nq, chunk, dv)
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)

    if schedule == "bb":
        # scan over kv tiles; every step touches ALL q tiles (masked) —
        # the bounding-box parallel space of the paper's Fig. 2.
        def step(carry, j):
            m, l, acc = carry
            kb = kt[:, :, j]
            vb = vt[:, :, j]
            sc = _gqa_scores(qt, kb)  # (B,Hkv,G,nq,bq,bk)
            qtile = jnp.arange(nq)
            causal = (qtile[:, None, None] * chunk + row[None]) >= (
                j * chunk + col[None]
            )
            sc = jnp.where(causal[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(-1))
            alpha = jnp.exp(m - m_new)
            pr = jnp.exp(sc - m_new[..., None])
            l_new = l * alpha + pr.sum(-1)
            acc_new = acc * alpha[..., None] + _gqa_out(pr, vb)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, nq, chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, nq, chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, nq, chunk, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(nq))
        out = acc / jnp.where(l == 0, 1.0, l)[..., None]
        return out.reshape(b, hq, s, dv).astype(q.dtype)

    # ---- folded simplex schedule ----
    p_idx = jnp.arange(nq // 2)

    def step(carry, j):
        m, l, acc, out = carry
        second = j > p_idx
        qsel = jnp.where(second, nq - 1 - p_idx, p_idx)  # (P,)
        ksel = jnp.where(second, j - p_idx - 1, j)
        start = (j == 0) | (j == p_idx + 1)
        last = (j == p_idx) | (j == nq)
        qb = jnp.take(qt, qsel, axis=3)  # (B,Hkv,G,P,bq,D)
        kb = jnp.take(kt, ksel, axis=2)  # (B,Hkv,P,bk,D)
        vb = jnp.take(vt, ksel, axis=2)
        # reset running state at segment starts
        m = jnp.where(start[:, None], jnp.full_like(m, NEG_INF), m)
        l = jnp.where(start[:, None], 0.0, l)
        acc = jnp.where(start[:, None, None], 0.0, acc)
        sc = _gqa_scores(qb, kb)  # (B,Hkv,G,P,bq,bk)
        on_diag = qsel == ksel
        mask = on_diag[:, None, None] & (col[None] > row[None])
        sc = jnp.where(mask[None, None, None], NEG_INF, sc)
        m_new = jnp.maximum(m, sc.max(-1))
        alpha = jnp.exp(m - m_new)
        pr = jnp.exp(sc - m_new[..., None])
        l_new = l * alpha + pr.sum(-1)
        acc_new = acc * alpha[..., None] + _gqa_out(pr, vb)
        # flush finished q tiles into the (nq+1)-padded output; slot -> its
        # q tile when finishing, else the trash tile nq.
        dest = jnp.where(last, qsel, nq)
        norm = acc_new / jnp.where(l_new == 0, 1.0, l_new)[..., None]
        out = out.at[:, :, :, dest].set(
            norm, mode="drop", unique_indices=False
        )
        return (m_new, l_new, acc_new, out), None

    P = nq // 2
    m0 = jnp.full((b, hkv, g, P, chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, P, chunk), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, P, chunk, dv), jnp.float32)
    o0 = jnp.zeros((b, hkv, g, nq + 1, chunk, dv), jnp.float32)
    (_, _, _, out), _ = jax.lax.scan(step, (m0, l0, a0, o0), jnp.arange(nq + 1))
    out = out[:, :, :, :nq]
    return out.reshape(b, hq, s, dv).astype(q.dtype)


def full_attention(q, k, v, *, chunk: int = 512, scale=None, mask=None):
    """Bidirectional (encoder / cross) attention, chunked over kv."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = hq // hkv
    if scale is None:
        scale = d**-0.5
    chunk = _best_chunk(sk, chunk)
    nk = sk // chunk
    qg = (q.astype(jnp.float32) * scale).astype(q.dtype)
    qg = qg.reshape(b, hkv, g, sq, d)
    kt = k.reshape(b, hkv, nk, chunk, d)
    vt = v.reshape(b, hkv, nk, chunk, dv)

    def step(carry, j):
        m, l, acc = carry
        sc = _gqa_scores(qg, kt[:, :, j])  # (B,Hkv,G,sq,bk)
        if mask is not None:
            mb = jax.lax.dynamic_slice_in_dim(mask, j * chunk, chunk, axis=-1)
            sc = jnp.where(mb[:, None, None], sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(-1))
        alpha = jnp.exp(m - m_new)
        pr = jnp.exp(sc - m_new[..., None])
        l_new = l * alpha + pr.sum(-1)
        acc_new = acc * alpha[..., None] + _gqa_out(pr, vt[:, :, j])
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(nk))
    out = acc / jnp.where(l == 0, 1.0, l)[..., None]
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


def simplex_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    impl: str = "auto",
    chunk: int = 512,
    schedule: str = "folded",
    scale: Optional[float] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Causal attention through the backend-aware dispatch (DESIGN.md §8).

    The production prefill/training entry: picks between the
    folded-simplex Pallas flash kernel
    (``kernels.flash_attention.flash_attention``) and the portable
    chunked XLA realization (``chunked_causal_attention``), resolving
    ``impl='auto'`` through the cached
    ``autotune.choose_attn_impl(seq, heads, head_dim, backend)``
    decision (roofline prior, measured ATTN rows when available, with
    the interpret step cap as a safety valve).

    Structural guards force the chunked path regardless of ``impl``:
    MLA-style ``v_head_dim != qk head_dim`` (the flash kernel assumes
    square tiles over one head dim) and ragged GQA group sizes.  The
    decode strip stays on ``decode_attention`` — a 1-token query has
    no simplex to fold (see the §8 decode-exclusion rationale).

    Args:
        q: Queries (B, Hq, S, D).
        k: Keys (B, Hkv, S, D); Hq must be a multiple of Hkv (GQA).
        v: Values (B, Hkv, S, Dv).
        impl: 'auto' | 'flash' | 'chunked', plus the benchmark knobs
            'flash-folded' / 'flash-bb' forcing the kernel schedule
            (any forced flash still falls back when the kernel cannot
            map the shape).
        chunk: Chunk size for the XLA path.
        schedule: 'folded' | 'bb' for the XLA path.
        scale: Score scale; None = D**-0.5.
        interpret: Pallas interpret override; None = policy default.

    Returns:
        Attention output, (B, Hq, S, Dv), in q's dtype.
    """
    if impl not in ("auto", "flash", "chunked", "flash-folded", "flash-bb"):
        raise ValueError(
            "impl must be 'auto', 'flash', 'chunked', 'flash-folded' or "
            f"'flash-bb'; got {impl!r}"
        )
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    flash_able = (
        impl != "chunked" and v.shape[-1] == d and hkv > 0 and hq % hkv == 0
    )
    if flash_able:
        from repro.autotune import choose_attn_impl

        dec = choose_attn_impl(s, hq, d)
        use_flash = dec.block_q > 0 and (
            dec.impl == "flash" if impl == "auto" else True
        )
        if use_flash:
            from repro.kernels.flash_attention import flash_attention

            if "-" in impl:
                kind = impl.split("-", 1)[1]
            else:
                kind = dec.kind if dec.kind in ("folded", "bb") else "folded"
            return flash_attention(
                q, k, v, kind=kind, block_q=dec.block_q,
                block_kv=dec.block_q, scale=scale, interpret=interpret,
            )
    return chunked_causal_attention(
        q, k, v, chunk=chunk, schedule=schedule, scale=scale
    )


def sharded_causal_attention(q, k, v, cfg, mesh):
    """Causal attention under explicit shard_map: q heads shard over
    'model', KV replicated and sliced locally to the group the shard's
    q heads need — so the folded schedule's tile gathers/scatters are
    *local* and GSPMD inserts zero collectives inside the scan (the
    §Perf fix for the per-step resharding pathology; see EXPERIMENTS.md
    §Perf iteration A2).

    Single-device (mesh-less) calls — the serving/training hot path on
    one chip — route through ``simplex_attention`` so prefill launches
    the folded flash kernel by default; shard_map bodies keep the
    chunked XLA realization (Pallas under GSPMD is out of the dispatch
    contract — DESIGN.md §8)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if mesh is None or "model" not in mesh.axis_names:
        return simplex_attention(
            q, k, v,
            impl=getattr(cfg, "attention_impl", "auto"),
            chunk=cfg.attention_chunk,
            schedule=cfg.attention_schedule,
        )
    if getattr(cfg, "tp_size", 16) <= 1:
        # no TP: attention is batch-local; shard_map over ALL axes on
        # batch keeps the folded tile walk collective-free.
        import numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        axes = tuple(mesh.axis_names)
        nsh = int(np.prod([mesh.shape[a] for a in axes]))
        if b % nsh:
            return chunked_causal_attention(
                q, k, v, chunk=cfg.attention_chunk,
                schedule=cfg.attention_schedule,
            )
        f = shard_map(
            lambda ql, kl, vl: chunked_causal_attention(
                ql, kl, vl, chunk=cfg.attention_chunk,
                schedule=cfg.attention_schedule,
            ),
            mesh=mesh,
            in_specs=(P(axes), P(axes), P(axes)),
            out_specs=P(axes),
            check_rep=False,
        )
        return f(q, k, v)
    msize = mesh.shape["model"]
    hq_loc = hq // msize if hq % msize == 0 else 0
    aligned = hq_loc > 0 and (
        hq_loc % group == 0 or (group % hq_loc == 0)
    )
    if not aligned:
        return chunked_causal_attention(
            q, k, v, chunk=cfg.attention_chunk, schedule=cfg.attention_schedule
        )
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    bspec = dp if b % dp_size == 0 else None
    kv_needed = max(hq_loc // group, 1)

    def body(ql, kl, vl):
        m = jax.lax.axis_index("model")
        kv_start = (m * hq_loc) // group
        kls = jax.lax.dynamic_slice_in_dim(kl, kv_start, kv_needed, axis=1)
        vls = jax.lax.dynamic_slice_in_dim(vl, kv_start, kv_needed, axis=1)
        return chunked_causal_attention(
            ql, kls, vls, chunk=cfg.attention_chunk,
            schedule=cfg.attention_schedule,
        )

    f = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(bspec, "model", None, None),
            P(bspec, None, None, None),
            P(bspec, None, None, None),
        ),
        out_specs=P(bspec, "model", None, None),
        check_rep=False,
    )
    return f(q, k, v)


def decode_attention(q, k_cache, v_cache, k_new, v_new, *, scale=None):
    """One-token attention against a full cache plus the new token.

    q: (B, Hq, 1, D); caches: (B, Hkv, S, D); k/v_new: (B, Hkv, 1, D).
    """
    b, hq, _, d = q.shape
    hkv = k_cache.shape[1]
    g = hq // hkv
    if scale is None:
        scale = d**-0.5
    qg = (q.astype(jnp.float32) * scale).astype(q.dtype).reshape(b, hkv, g, 1, d)
    sc_c = _gqa_scores(qg, k_cache)  # (B,Hkv,G,1,S)
    sc_n = _gqa_scores(qg, k_new)  # (B,Hkv,G,1,1)
    m = jnp.maximum(sc_c.max(-1), sc_n.max(-1))[..., None]
    pc = jnp.exp(sc_c - m)
    pn = jnp.exp(sc_n - m)
    l = pc.sum(-1, keepdims=True) + pn.sum(-1, keepdims=True)
    out = (_gqa_out(pc, v_cache) + _gqa_out(pn, v_new)) / l.astype(jnp.float32)
    return out.reshape(b, hq, 1, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------


def attn_init(key, cfg, dtype):
    """GQA projection params: wq (D, Hq*hd), wk/wv (D, Hkv*hd), wo."""
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, hq * hd), dtype),
        "wk": dense_init(k2, (d, hkv * hd), dtype),
        "wv": dense_init(k3, (d, hkv * hd), dtype),
        "wo": dense_init(k4, (hq * hd, d), dtype),
    }


def attn_apply(
    p,
    cfg,
    x,
    positions,
    *,
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    mode: str = "train",
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    bidirectional: bool = False,
    positions3=None,
    mesh=None,
):
    """Returns (out, new_cache).  Modes:
    train/prefill — full-sequence causal (or bidirectional) attention;
    decode        — x is (B, 1, D) attending to ``cache``.
    ``cross_kv``  — use the given encoder K/V (cross-attention).
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = x.dtype
    q = jnp.dot(x, p["wq"].astype(dt)).reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    if cross_kv is None:
        k = jnp.dot(x, p["wk"].astype(dt)).reshape(b, s, hkv, hd)
        v = jnp.dot(x, p["wv"].astype(dt)).reshape(b, s, hkv, hd)
        k = k.transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        if cfg.mrope_sections is not None and positions3 is not None:
            from .layers import mrope

            q = mrope(q, positions3, cfg.mrope_sections, cfg.rope_theta)
            k = mrope(k, positions3, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
    else:
        k, v = cross_kv

    new_cache = None
    if mode == "decode" and cross_kv is None:
        kc, vc = cache
        o = decode_attention(q, kc, vc, k, v)
        new_cache = (kc, vc, k, v)  # caller appends (ring/position update)
    elif bidirectional or cross_kv is not None:
        o = full_attention(q, k, v, chunk=cfg.attention_chunk)
        if mode == "prefill" and cross_kv is None:
            new_cache = (k, v)
    else:
        o = sharded_causal_attention(q, k, v, cfg, mesh)
        if mode == "prefill":
            new_cache = (k, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return jnp.dot(o, p["wo"].astype(dt)), new_cache


def init_kv_cache(cfg, batch, seq, dtype):
    """Zeroed decode K/V cache pair, each (batch, Hkv, seq, hd)."""
    hkv, hd = cfg.n_kv_heads, cfg.hd
    return (
        jnp.zeros((batch, hkv, seq, hd), dtype),
        jnp.zeros((batch, hkv, seq, hd), dtype),
    )
