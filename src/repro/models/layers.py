"""Shared model layers: norms, RoPE / M-RoPE, embeddings, SwiGLU."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "rmsnorm_init",
    "rmsnorm",
    "layernorm_init",
    "layernorm",
    "rope",
    "mrope",
    "swiglu_init",
    "swiglu",
    "embed_init",
    "embed",
]


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (shape[-2] is fan-in for 2D)."""
    fan_in = shape[0] if len(shape) == 2 else shape[-2]
    if scale is None:
        scale = fan_in**-0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * scale).astype(dtype)


def rmsnorm_init(dim, dtype):
    return {"w": jnp.ones((dim,), dtype)}


def rmsnorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * p["w"].astype(dt)


def layernorm_init(dim, dtype):
    return {"w": jnp.ones((dim,), dtype), "b": jnp.zeros((dim,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * p["w"].astype(dt) + p["b"].astype(dt)


def _rope_angles(positions, dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, dim/2), f32."""
    half = dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def rope(x, positions, theta: float = 1e6):
    """NeoX-style rotary embedding.  x: (B, H, S, D); positions: (B, S)."""
    d = x.shape[-1]
    cos, sin = _rope_angles(positions, d, theta)  # (B, S, D/2)
    cos = cos[:, None]  # (B, 1, S, D/2)
    sin = sin[:, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope(x, positions3, sections: Tuple[int, int, int], theta: float = 1e6):
    """Qwen2-VL multimodal RoPE.

    x: (B, H, S, D); positions3: (B, S, 3) for (t, h, w) streams;
    ``sections`` split D/2 frequency slots among the three streams.
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # frequency slot -> which position stream drives it
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(sec_id[None, None, :], positions3.shape[:2] + (half,)),
        axis=-1,
    )  # (B, S, half)
    ang = pos * freq  # (B, S, half)
    cos = jnp.cos(ang)[:, None]
    sin = jnp.sin(ang)[:, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": dense_init(k1, (d_model, d_ff), dtype),
        "w3": dense_init(k2, (d_model, d_ff), dtype),
        "w2": dense_init(k3, (d_ff, d_model), dtype),
    }


def swiglu(p, x):
    dt = x.dtype
    g = jnp.dot(x, p["w1"].astype(dt))
    u = jnp.dot(x, p["w3"].astype(dt))
    return jnp.dot(jax.nn.silu(g) * u, p["w2"].astype(dt))


def embed_init(key, vocab, d_model, dtype):
    return {"e": dense_init(key, (vocab, d_model), dtype, scale=1.0)}


def embed(p, tokens, act_dtype):
    return jnp.take(p["e"], tokens, axis=0).astype(act_dtype)
