"""Multi-head Latent Attention (DeepSeek-V3), with decode absorption.

Train/prefill: Q via low-rank (w_dq -> norm -> w_uq), K/V via the shared
compressed latent c_kv (kv_lora_rank) plus a decoupled RoPE key (shared
across heads).  Decode: the absorbed form — W_uk folds into the query and
W_uv applies after attention over the latent — so the cache per token is
only (kv_lora_rank + qk_rope_dim) floats regardless of head count.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import chunked_causal_attention, sharded_causal_attention
from .layers import dense_init, rmsnorm, rmsnorm_init, rope

__all__ = ["mla_init", "mla_apply", "init_mla_cache"]


def mla_init(key, cfg, dtype):
    """Initialize the MLA parameter tree.

    Args:
        key: PRNG key.
        cfg: Model config carrying ``cfg.mla`` (rank/head-dim fields),
            ``cfg.d_model`` and ``cfg.n_heads``.
        dtype: Parameter dtype.

    Returns:
        Dict of dense/rmsnorm parameters: the low-rank Q path
        (``w_dq``/``q_norm``/``w_uq``), the shared compressed KV latent
        (``w_dkv``/``kv_norm``), the decoupled RoPE key ``w_kr``, the
        per-head expansions ``w_uk``/``w_uv``, and the output ``wo``.
    """
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 8)
    qk_head = m.qk_nope_dim + m.qk_rope_dim
    return {
        "w_dq": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": rmsnorm_init(m.q_lora_rank, dtype),
        "w_uq": dense_init(ks[1], (m.q_lora_rank, h * qk_head), dtype),
        "w_dkv": dense_init(ks[2], (d, m.kv_lora_rank), dtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "w_kr": dense_init(ks[3], (d, m.qk_rope_dim), dtype),
        "w_uk": dense_init(ks[4], (m.kv_lora_rank, h * m.qk_nope_dim), dtype),
        "w_uv": dense_init(ks[5], (m.kv_lora_rank, h * m.v_head_dim), dtype),
        "wo": dense_init(ks[6], (h * m.v_head_dim, d), dtype),
    }


def _project_q(p, cfg, x, positions):
    """Low-rank query projection -> ``(q_nope, q_pe)``, both (B,H,S,*)."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    dt = x.dtype
    cq = rmsnorm(p["q_norm"], jnp.dot(x, p["w_dq"].astype(dt)), cfg.norm_eps)
    q = jnp.dot(cq, p["w_uq"].astype(dt)).reshape(
        b, s, h, m.qk_nope_dim + m.qk_rope_dim
    )
    q_nope, q_pe = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_pe = rope(q_pe.transpose(0, 2, 1, 3), positions, cfg.rope_theta)
    return q_nope.transpose(0, 2, 1, 3), q_pe  # (B,H,S,*)


def mla_apply(
    p,
    cfg,
    x,
    positions,
    *,
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    mode: str = "train",
    mesh=None,
):
    """Apply one MLA block.

    Args:
        p: Parameter tree from ``mla_init``.
        cfg: Model config (``cfg.mla`` ranks/head dims).
        x: Input activations ``(B, S, d_model)``.
        positions: Token positions for RoPE.
        cache: ``(c_kv (B,S,L), k_pe (B,S,R))`` latent cache; decode
            only.
        mode: ``'train'`` / ``'prefill'`` (full attention) or
            ``'decode'`` (absorbed attention over the latent cache).
        mesh: Optional device mesh for sharded attention.

    Returns:
        ``(out, new_cache)`` — ``new_cache`` is the latent pair after
        prefill, the extended cache tuple in decode, else None.
    """
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    dt = x.dtype
    q_nope, q_pe = _project_q(p, cfg, x, positions)

    c_kv_new = rmsnorm(p["kv_norm"], jnp.dot(x, p["w_dkv"].astype(dt)), cfg.norm_eps)
    k_pe_new = rope(
        jnp.dot(x, p["w_kr"].astype(dt))[:, None], positions, cfg.rope_theta
    )[:, 0]  # (B,S,R)

    if mode != "decode":
        # full (non-absorbed) attention: expand K and V per head
        k_nope = jnp.dot(c_kv_new, p["w_uk"].astype(dt)).reshape(
            b, s, h, m.qk_nope_dim
        ).transpose(0, 2, 1, 3)
        v = jnp.dot(c_kv_new, p["w_uv"].astype(dt)).reshape(
            b, s, h, m.v_head_dim
        ).transpose(0, 2, 1, 3)
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe_new[:, None], (b, h, s, m.qk_rope_dim))],
            axis=-1,
        )
        # v_head_dim != qk head dim: the simplex_attention dispatch
        # detects the rectangular value and keeps the chunked XLA path
        # (the flash kernel maps square tiles only — DESIGN.md §8).
        o = sharded_causal_attention(q, k, v, cfg, mesh)  # (B,H,S,vd)
        out = jnp.dot(
            o.transpose(0, 2, 1, 3).reshape(b, s, h * m.v_head_dim),
            p["wo"].astype(dt),
        )
        new_cache = (c_kv_new, k_pe_new) if mode == "prefill" else None
        return out, new_cache

    # ---- absorbed decode: attend over the latent cache ----
    c_kv, k_pe = cache  # (B,S,L), (B,S,R)
    w_uk = p["w_uk"].astype(dt).reshape(m.kv_lora_rank, h, m.qk_nope_dim)
    # fold W_uk into q:  (B,H,1,nope) x (L,H,nope) -> (B,H,1,L)
    q_abs = jnp.einsum("bhqn,lhn->bhql", q_nope, w_uk)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    sc = (
        jnp.einsum("bhql,bsl->bhqs", q_abs, c_kv, preferred_element_type=jnp.float32)
        + jnp.einsum(
            "bhqr,bsr->bhqs", q_pe, k_pe, preferred_element_type=jnp.float32
        )
    ) * scale
    sc_new = (
        jnp.einsum(
            "bhql,bl->bhq", q_abs, c_kv_new[:, 0], preferred_element_type=jnp.float32
        )
        + jnp.einsum(
            "bhqr,br->bhq", q_pe, k_pe_new[:, 0], preferred_element_type=jnp.float32
        )
    )[..., None] * scale
    mx = jnp.maximum(sc.max(-1, keepdims=True), sc_new)
    pc = jnp.exp(sc - mx)
    pn = jnp.exp(sc_new - mx)
    denom = pc.sum(-1, keepdims=True) + pn
    ctx = (
        jnp.einsum("bhqs,bsl->bhql", pc.astype(dt), c_kv)
        + pn.astype(dt) * c_kv_new[:, None, 0:1]
    ) / denom.astype(dt)
    w_uv = p["w_uv"].astype(dt).reshape(m.kv_lora_rank, h, m.v_head_dim)
    o = jnp.einsum("bhql,lhv->bhqv", ctx, w_uv)  # (B,H,1,vd)
    out = jnp.dot(
        o.transpose(0, 2, 1, 3).reshape(b, s, h * m.v_head_dim), p["wo"].astype(dt)
    )
    return out, (c_kv, k_pe, c_kv_new, k_pe_new)


def init_mla_cache(cfg, batch, seq, dtype):
    """Zero-filled latent decode cache ``(c_kv, k_pe)``.

    The whole point of MLA decode: per token the cache holds only
    ``kv_lora_rank + qk_rope_dim`` floats, independent of head count.

    Args:
        cfg: Model config carrying ``cfg.mla``.
        batch: Batch size.
        seq: Cache capacity in tokens.
        dtype: Cache dtype.

    Returns:
        ``(c_kv (B,S,kv_lora_rank), k_pe (B,S,qk_rope_dim))``.

    Example:
        >>> from types import SimpleNamespace
        >>> cfg = SimpleNamespace(
        ...     mla=SimpleNamespace(kv_lora_rank=4, qk_rope_dim=2))
        >>> c_kv, k_pe = init_mla_cache(cfg, 1, 3, "float32")
        >>> c_kv.shape, k_pe.shape
        ((1, 3, 4), (1, 3, 2))
    """
    m = cfg.mla
    return (
        jnp.zeros((batch, seq, m.kv_lora_rank), dtype),
        jnp.zeros((batch, seq, m.qk_rope_dim), dtype),
    )
