"""Model facade: init / loss / prefill / decode / input_specs.

One class drives all 10 assigned architectures from their ArchConfig:
decoder-only LMs (dense, MoE, MLA, hybrid, ssm), the VLM stub (patch
embeddings prepended, M-RoPE positions), and the audio encoder-decoder
(frame-embedding encoder + cross-attending decoder).  The dry-run lowers
``train_step`` / ``prefill_step`` / ``serve_step`` built from these.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, LayerSpec, ShapeCfg

from .layers import embed, embed_init, rmsnorm, rmsnorm_init, dense_init
from .transformer import (
    block_apply,
    block_init,
    init_block_cache,
    stack_apply,
    stack_init,
)

__all__ = ["Model"]


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.specs = tuple(cfg.period)
        self.prefix_specs = tuple(cfg.prefix_spec)
        self.is_encdec = cfg.encoder_layers > 0
        self.pdtype = jnp.dtype(cfg.param_dtype)
        self.adtype = jnp.dtype(cfg.act_dtype)

    # ------------------------------------------------------------------ init

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        params: Dict[str, Any] = {
            "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, self.pdtype),
            "final_norm": rmsnorm_init(cfg.d_model, self.pdtype),
            "stack": stack_init(
                ks[1], cfg, self.specs, cfg.n_periods, self.pdtype,
                cross=self.is_encdec,
            ),
        }
        if self.prefix_specs:
            params["prefix"] = {
                f"p{i}": block_init(
                    jax.random.fold_in(ks[2], i), cfg, s, self.pdtype,
                    cross=self.is_encdec,
                )
                for i, s in enumerate(self.prefix_specs)
            }
        if not cfg.tie_embeddings:
            params["unembed"] = dense_init(
                ks[3], (cfg.d_model, cfg.vocab), self.pdtype
            )
        if self.is_encdec:
            params["encoder"] = {
                "stack": stack_init(
                    ks[4], cfg, (LayerSpec("attn", "dense"),),
                    cfg.encoder_layers, self.pdtype,
                ),
                "final_norm": rmsnorm_init(cfg.d_model, self.pdtype),
            }
        if cfg.mtp:
            params["mtp"] = {
                "proj": dense_init(ks[5], (2 * cfg.d_model, cfg.d_model), self.pdtype),
                "block": block_init(ks[6], cfg, LayerSpec("attn", "dense"), self.pdtype),
                "norm": rmsnorm_init(cfg.d_model, self.pdtype),
            }
        return params

    # ------------------------------------------------------------ embeddings

    def _embed_inputs(self, params, batch):
        """Returns (embeds (B,S,d), positions (B,S), positions3 or None)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed(params["embed"], tokens, self.adtype)
        b = tokens.shape[0]
        if cfg.n_patches and "patches" in batch:
            patches = batch["patches"].astype(self.adtype)  # (B,P,d)
            x = jnp.concatenate([patches, x], axis=1)
            s = x.shape[1]
            pos3 = self._mrope_positions(b, s)
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            return x, positions, pos3
        s = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        return x, positions, None

    def _mrope_positions(self, b, s):
        cfg = self.cfg
        p = cfg.n_patches
        side = int(math.isqrt(p)) or 1
        t = jnp.concatenate([jnp.zeros((p,), jnp.int32), jnp.arange(s - p) + 1])
        hh = jnp.concatenate(
            [jnp.arange(p) // side, jnp.arange(s - p) + 1 + (side - 1)]
        )
        ww = jnp.concatenate(
            [jnp.arange(p) % side, jnp.arange(s - p) + 1 + (side - 1)]
        )
        pos3 = jnp.stack([t, hh, ww], axis=-1).astype(jnp.int32)  # (S,3)
        return jnp.broadcast_to(pos3[None], (b, s, 3))

    # --------------------------------------------------------------- forward

    def _backbone(self, params, x, positions, *, caches=None, mode="train",
                  mesh=None, enc_out=None, positions3=None):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        new_caches: Dict[str, Any] = {}
        if self.prefix_specs:
            pc = {}
            for i, spec in enumerate(self.prefix_specs):
                c_i = caches["prefix"][f"p{i}"] if caches else None
                cross_cache = (
                    c_i.get("cross") if (c_i and mode == "decode") else None
                )
                x, nc, a = block_apply(
                    params["prefix"][f"p{i}"], cfg, spec, x, positions,
                    cache=c_i, mode=mode, mesh=mesh, enc_out=enc_out,
                    cross_cache=cross_cache, positions3=positions3,
                )
                if mode == "decode" and c_i and "cross" in c_i:
                    nc["cross"] = c_i["cross"]
                pc[f"p{i}"] = nc
                aux = aux + a
            new_caches["prefix"] = pc
        x, sc, a = stack_apply(
            params["stack"], cfg, self.specs, cfg.n_periods, x, positions,
            caches=caches["stack"] if caches else None, mode=mode, mesh=mesh,
            enc_out=enc_out, positions3=positions3,
        )
        new_caches["stack"] = sc
        aux = aux + a
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x, new_caches, aux

    def _encode(self, params, src_embeds):
        cfg = self.cfg
        b, s, _ = src_embeds.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        x, _, _ = stack_apply(
            params["encoder"]["stack"], cfg, (LayerSpec("attn", "dense"),),
            cfg.encoder_layers, src_embeds.astype(self.adtype), positions,
            mode="train", bidirectional=True,
        )
        return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)

    def _logits(self, params, x):
        cfg = self.cfg
        w = (
            params["embed"]["e"].T if cfg.tie_embeddings else params["unembed"]
        ).astype(self.adtype)
        return jnp.dot(x, w)

    # ------------------------------------------------------------------ loss

    def loss(self, params, batch, mesh=None):
        """Next-token CE (+ MoE aux + MTP).  batch['tokens']: (B, S+1)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        inp = {**batch, "tokens": tokens[:, :-1]}
        labels = tokens[:, 1:]
        x, positions, pos3 = self._embed_inputs(params, inp)
        enc_out = None
        if self.is_encdec:
            enc_out = self._encode(params, batch["src_embeds"])
        h, _, aux = self._backbone(
            params, x, positions, mode="train", mesh=mesh, enc_out=enc_out,
            positions3=pos3,
        )
        n_text = labels.shape[1]
        h_text = h[:, -n_text:]  # skip patch positions (vlm)
        logits = self._logits(params, h_text)
        ce = _cross_entropy(logits, labels)
        total = ce + aux
        if cfg.mtp:
            total = total + 0.3 * self._mtp_loss(params, h_text, tokens, mesh)
        return total, {"ce": ce, "aux": aux}

    def _mtp_loss(self, params, h, tokens, mesh):
        """DeepSeek-V3 multi-token prediction: depth-1 extra head that
        predicts token t+2 from [h_t ; emb(token_{t+1})]."""
        cfg = self.cfg
        emb_next = embed(params["embed"], tokens[:, 1:-1], self.adtype)
        h_in = jnp.concatenate([h[:, :-1], emb_next], axis=-1)
        x = jnp.dot(h_in, params["mtp"]["proj"].astype(self.adtype))
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        x, _, _ = block_apply(
            params["mtp"]["block"], cfg, LayerSpec("attn", "dense"), x,
            positions, mode="train", mesh=mesh,
        )
        x = rmsnorm(params["mtp"]["norm"], x, cfg.norm_eps)
        return _cross_entropy(self._logits(params, x), tokens[:, 2:])

    # ------------------------------------------------------- prefill / decode

    def prefill(self, params, batch, mesh=None):
        """Full-sequence forward filling caches; returns (last_logits, caches)."""
        inp = dict(batch)
        x, positions, pos3 = self._embed_inputs(params, inp)
        enc_out = self._encode(params, batch["src_embeds"]) if self.is_encdec else None
        h, caches, _ = self._backbone(
            params, x, positions, mode="prefill", mesh=mesh, enc_out=enc_out,
            positions3=pos3,
        )
        return self._logits(params, h[:, -1:]), caches

    def decode(self, params, caches, batch, mesh=None):
        """One token against full caches.  batch['tokens']: (B, 1);
        batch['pos']: (B,) absolute position of the new token."""
        x = embed(params["embed"], batch["tokens"], self.adtype)
        b = x.shape[0]
        positions = batch["pos"][:, None]
        pos3 = None
        if self.cfg.mrope_sections is not None:
            pos3 = jnp.broadcast_to(
                positions[..., None], (b, 1, 3)
            ).astype(jnp.int32)
        h, new_caches, _ = self._backbone(
            params, x, positions, caches=caches, mode="decode", mesh=mesh,
            positions3=pos3,
        )
        return self._logits(params, h), new_caches

    # ----------------------------------------------------------------- caches

    def init_cache(self, batch, seq, dtype=None):
        cfg = self.cfg
        dtype = dtype or self.adtype
        caches: Dict[str, Any] = {}
        if self.prefix_specs:
            caches["prefix"] = {
                f"p{i}": init_block_cache(
                    cfg, s, batch, seq, dtype, cross=self.is_encdec
                )
                for i, s in enumerate(self.prefix_specs)
            }

        def one_period():
            return {
                f"l{i}": init_block_cache(
                    cfg, s, batch, seq, dtype, cross=self.is_encdec
                )
                for i, s in enumerate(self.specs)
            }

        p0 = one_period()
        caches["stack"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_periods,) + x.shape), p0
        )
        return caches

    # ------------------------------------------------------------ input specs

    def input_specs(self, shape: ShapeCfg) -> Dict[str, Any]:
        """ShapeDtypeStructs for every model input of the given cell —
        weak-type-correct, shardable, no device allocation."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        if shape.mode == "train":
            batch: Dict[str, Any] = {"tokens": sds((b, s + 1), i32)}
        elif shape.mode == "prefill":
            batch = {"tokens": sds((b, s), i32)}
        else:  # decode
            batch = {"tokens": sds((b, 1), i32), "pos": sds((b,), i32)}
        if cfg.n_patches:
            if shape.mode != "decode":
                # patches replace the leading n_patches text positions
                batch["tokens"] = sds(
                    (b, batch["tokens"].shape[1] - cfg.n_patches), i32
                )
                batch["patches"] = sds(
                    (b, cfg.n_patches, cfg.d_model), jnp.bfloat16
                )
        if self.is_encdec and shape.mode != "decode":
            batch["src_embeds"] = sds((b, s, cfg.d_model), jnp.bfloat16)
        return batch


def _cross_entropy(logits, labels):
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)
