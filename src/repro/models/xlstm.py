"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel training form)
and sLSTM (scalar memory, strictly recurrent), after arXiv:2405.04517.

mLSTM is a gated linear-attention cell: state C_t = f_t C_{t-1} +
i_t k_t v_t^T with exponential gates stabilized by a running max m_t.
Training uses the chunkwise form: within a chunk the decay matrix
D[t,s] = A_t - A_s + b_s (s <= t) is *lower triangular* — the same
2-simplex iteration space the paper maps (the chunk loop walks only the
causal chunk pairs); across chunks a sequential scan carries (C, n, m).
Decode carries the same (C, n, m) — O(1) memory per token, which is why
xlstm runs the long_500k cell.

sLSTM keeps per-head scalar state with exponential gating and a
normalizer; its recurrence is not parallelizable (by design — the
paper's argument for state tracking), so training scans over time.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, layernorm, layernorm_init

__all__ = [
    "mlstm_init",
    "mlstm_apply",
    "slstm_init",
    "slstm_apply",
    "init_mlstm_cache",
    "init_slstm_cache",
]


def _mdims(cfg):
    xc = cfg.xlstm
    dp = int(cfg.d_model * xc.proj_factor_mlstm)
    h = xc.n_heads
    return xc, dp, h, dp // h


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg, dtype):
    xc, dp, h, dh = _mdims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        "up": dense_init(ks[0], (d, 2 * dp), dtype),
        "conv_w": dense_init(ks[1], (xc.d_conv, dp), dtype, scale=xc.d_conv**-0.5),
        "conv_b": jnp.zeros((dp,), dtype),
        "wq": dense_init(ks[2], (dp, dp), dtype),
        "wk": dense_init(ks[3], (dp, dp), dtype),
        "wv": dense_init(ks[4], (dp, dp), dtype),
        "wi": dense_init(ks[5], (dp, h), jnp.float32, scale=0.02),
        "wf": dense_init(ks[6], (dp, h), jnp.float32, scale=0.02),
        "down": dense_init(ks[7], (dp, d), dtype),
        "skip_scale": jnp.ones((dp,), dtype),
    }


def _mlstm_qkvgates(p, cfg, x_in, conv_tail=None):
    xc, dp, h, dh = _mdims(cfg)
    from .mamba import _causal_conv

    xc_out, new_tail = _causal_conv(x_in, p["conv_w"], p["conv_b"], conv_tail)
    x_conv = jax.nn.silu(xc_out)
    dt = x_in.dtype
    b, s, _ = x_in.shape

    def heads(t):
        return t.reshape(b, s, h, dh).transpose(0, 2, 1, 3)  # (B,H,S,dh)

    q = heads(jnp.dot(x_conv, p["wq"].astype(dt)))
    k = heads(jnp.dot(x_conv, p["wk"].astype(dt))) * (dh**-0.5)
    v = heads(jnp.dot(x_in, p["wv"].astype(dt)))
    ig = jnp.einsum("bsd,dh->bhs", x_conv.astype(jnp.float32), p["wi"])
    fg = jnp.einsum("bsd,dh->bhs", x_conv.astype(jnp.float32), p["wf"])
    return q, k, v, ig, fg, x_conv, new_tail


def _mlstm_step(c, n, m, q, k, v, ig, fg):
    """Single recurrent step.  c: (B,H,dh,dh), n: (B,H,dh), m: (B,H);
    q,k,v: (B,H,dh); ig,fg: (B,H)."""
    logf = jax.nn.log_sigmoid(fg)
    m_new = jnp.maximum(logf + m, ig)
    f_s = jnp.exp(logf + m - m_new)[..., None]
    i_s = jnp.exp(ig - m_new)[..., None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c_new = f_s[..., None] * c + i_s[..., None] * kf[..., :, None] * vf[..., None, :]
    n_new = f_s * n + i_s * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhij,bhi->bhj", c_new, qf)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhi,bhi->bh", n_new, qf)), jnp.exp(-m_new)
    )[..., None]
    return c_new, n_new, m_new, num / den


def mlstm_recurrent(p, cfg, x_in, state):
    """Step-by-step reference/decode path.  x_in: (B, S, dp-in space)."""
    xc, dp, h, dh = _mdims(cfg)
    c, n, m, conv_tail = state
    q, k, v, ig, fg, _, new_tail = _mlstm_qkvgates(p, cfg, x_in, conv_tail)

    def step(carry, t):
        c, n, m = carry
        c, n, m, out = _mlstm_step(
            c, n, m, q[:, :, t], k[:, :, t], v[:, :, t], ig[:, :, t], fg[:, :, t]
        )
        return (c, n, m), out

    (c, n, m), outs = jax.lax.scan(step, (c, n, m), jnp.arange(x_in.shape[1]))
    outs = jnp.moveaxis(outs, 0, 2)  # (B,H,S,dh)
    return outs, (c, n, m, new_tail)


def mlstm_chunkwise(p, cfg, x_in):
    """Chunkwise-parallel training form.  x_in: (B, S, dp)."""
    xc, dp, h, dh = _mdims(cfg)
    b, s, _ = x_in.shape
    L = min(xc.chunk, s)
    assert s % L == 0
    nc = s // L
    q, k, v, ig, fg, _, _ = _mlstm_qkvgates(p, cfg, x_in)
    # chunked views: (B,H,nc,L,*)
    qc = q.reshape(b, h, nc, L, dh)
    kc = k.reshape(b, h, nc, L, dh)
    vc = v.reshape(b, h, nc, L, dh)
    igc = ig.reshape(b, h, nc, L)
    logf = jax.nn.log_sigmoid(fg).reshape(b, h, nc, L)
    A = jnp.cumsum(logf, axis=-1)  # within-chunk inclusive decay
    row = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    tri = col <= row  # the 2-simplex of the intra-chunk interaction

    def step(carry, ci):
        c, n, m = carry  # (B,H,dh,dh) f32, (B,H,dh) f32, (B,H) f32
        a = A[:, :, ci]  # (B,H,L)
        bgate = igc[:, :, ci]
        # intra-chunk log weights D[t,s] = a_t - a_s + b_s  (s<=t)
        dmat = a[..., :, None] - a[..., None, :] + bgate[..., None, :]
        dmat = jnp.where(tri, dmat, -jnp.inf)
        m_intra = dmat.max(-1)  # (B,H,L)
        m_state = m[..., None] + a  # (B,H,L)
        m_t = jnp.maximum(m_intra, m_state)
        w = jnp.exp(dmat - m_t[..., None])  # (B,H,L,L)
        qf = qc[:, :, ci].astype(jnp.float32)
        kf = kc[:, :, ci].astype(jnp.float32)
        vf = vc[:, :, ci].astype(jnp.float32)
        scores = jnp.einsum("bhtd,bhsd->bhts", qf, kf) * w
        num = jnp.einsum("bhts,bhsd->bhtd", scores, vf)
        num = num + jnp.exp(m_state - m_t)[..., None] * jnp.einsum(
            "bhij,bhti->bhtj", c, qf
        )
        den_intra = scores.sum(-1)
        den_state = jnp.exp(m_state - m_t) * jnp.einsum("bhi,bhti->bht", n, qf)
        den = jnp.maximum(jnp.abs(den_intra + den_state), jnp.exp(-m_t))
        out = num / den[..., None]  # (B,H,L,dh)
        # chunk-end state update
        a_tot = a[..., -1]  # (B,H)
        g = a_tot[..., None] - a + bgate  # decay from pos s to chunk end
        m_next = jnp.maximum(m + a_tot, g.max(-1))
        scale_c = jnp.exp(m + a_tot - m_next)
        wk = jnp.exp(g - m_next[..., None])  # (B,H,L)
        c_next = scale_c[..., None, None] * c + jnp.einsum(
            "bhs,bhsi,bhsj->bhij", wk, kf, vf
        )
        n_next = scale_c[..., None] * n + jnp.einsum("bhs,bhsi->bhi", wk, kf)
        return (c_next, n_next, m_next), out

    c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h), -jnp.inf, jnp.float32)
    (c, n, m), outs = jax.lax.scan(step, (c0, n0, m0), jnp.arange(nc))
    outs = jnp.moveaxis(outs, 0, 2).reshape(b, h, s, dh)
    return outs, (c, n, m)


def mlstm_apply(p, cfg, x, *, cache=None, mode: str = "train"):
    """Full mLSTM block: LN -> up-proj -> conv/qkv/gates -> cell -> gated
    down-proj with residual handled by the caller."""
    xc, dp, h, dh = _mdims(cfg)
    b, s, d = x.shape
    dt = x.dtype
    xz = jnp.dot(x, p["up"].astype(dt))
    x_in, z = jnp.split(xz, 2, axis=-1)
    if mode == "decode":
        outs, new_state = mlstm_recurrent(p, cfg, x_in, cache)
    else:
        outs, st = mlstm_chunkwise(p, cfg, x_in)
        tail = None
        if mode == "prefill":
            from .mamba import _causal_conv

            _, tail = _causal_conv(x_in, p["conv_w"], p["conv_b"])
            new_state = st + (tail,)
        else:
            new_state = None
    y = outs.transpose(0, 2, 1, 3).reshape(b, s, dp).astype(dt)
    y = y + p["skip_scale"].astype(dt) * x_in
    out = jnp.dot(y * jax.nn.silu(z), p["down"].astype(dt))
    return out, new_state


def init_mlstm_cache(cfg, batch, dtype):
    xc, dp, h, dh = _mdims(cfg)
    return (
        jnp.zeros((batch, h, dh, dh), jnp.float32),
        jnp.zeros((batch, h, dh), jnp.float32),
        jnp.full((batch, h), -1e30, jnp.float32),
        jnp.zeros((batch, xc.d_conv - 1, dp), dtype),
    )


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def _sdims(cfg):
    xc = cfg.xlstm
    h = xc.n_heads
    dh = cfg.d_model // h
    dff = int(cfg.d_model * xc.proj_factor_slstm)
    dff = ((dff + 63) // 64) * 64  # hardware-aligned (and TP-divisible)
    return xc, h, dh, dff


def slstm_init(key, cfg, dtype):
    xc, h, dh, dff = _sdims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    return {
        # input projections for (z, i, f, o), fused
        "w_in": dense_init(ks[0], (d, 4 * d), dtype),
        # per-head recurrent kernels for (z, i, f, o)
        "r": dense_init(ks[1], (4, h, dh, dh), dtype, scale=dh**-0.5),
        "bias": jnp.zeros((4, d), jnp.float32),
        "w_gn": jnp.ones((d,), dtype),
        "up1": dense_init(ks[2], (d, dff), dtype),
        "up2": dense_init(ks[3], (d, dff), dtype),
        "down": dense_init(ks[4], (dff, d), dtype),
    }


def slstm_apply(p, cfg, x, *, cache=None, mode: str = "train"):
    """sLSTM block: recurrent scalar-memory cell + gated FFN.

    x: (B, S, d).  cache: (c, n, h_prev, m) each (B, d) — d = H*dh.
    """
    xc, h, dh, dff = _sdims(cfg)
    b, s, d = x.shape
    dt = x.dtype
    zifo = jnp.dot(x, p["w_in"].astype(dt))  # (B,S,4d)

    if cache is None:
        cache = init_slstm_cache(cfg, b, dt)
    c0, n0, h0, m0 = cache

    r = p["r"].astype(dt)

    def step(carry, t):
        c, n, h_prev, m = carry  # (B,d) f32 except h_prev in dt
        hp = h_prev.reshape(b, h, dh).astype(dt)
        rec = jnp.einsum("bhi,ghij->gbhj", hp, r).reshape(4, b, d)
        pre = zifo[:, t].reshape(b, 4, d).transpose(1, 0, 2).astype(jnp.float32)
        pre = pre + rec.astype(jnp.float32) + p["bias"][:, None, :]
        zt = jnp.tanh(pre[0])
        it = pre[1]
        ft = pre[2]
        ot = jax.nn.sigmoid(pre[3])
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        i_s = jnp.exp(it - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c_new = f_s * c + i_s * zt
        n_new = f_s * n + i_s
        h_new = ot * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new.astype(dt), m_new), h_new.astype(dt)

    (c, n, h_last, m), hs = jax.lax.scan(step, (c0, n0, h0, m0), jnp.arange(s))
    hs = jnp.moveaxis(hs, 0, 1)  # (B,S,d)
    # headwise group norm
    hf = hs.astype(jnp.float32).reshape(b, s, h, dh)
    hf = (hf - hf.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        hf.var(-1, keepdims=True) + cfg.norm_eps
    )
    hs = (hf.reshape(b, s, d) * p["w_gn"].astype(jnp.float32)).astype(dt)
    # gated FFN (proj factor 4/3, xLSTM paper's post-sLSTM MLP)
    u = jnp.dot(hs, p["up1"].astype(dt))
    g = jnp.dot(hs, p["up2"].astype(dt))
    out = jnp.dot(jax.nn.gelu(u) * g, p["down"].astype(dt))
    new_cache = (c, n, h_last, m) if mode in ("prefill", "decode") else None
    return out, new_cache


def init_slstm_cache(cfg, batch, dtype):
    d = cfg.d_model
    return (
        jnp.zeros((batch, d), jnp.float32),
        jnp.zeros((batch, d), jnp.float32),
        jnp.zeros((batch, d), dtype),
        jnp.full((batch, d), -1e30, jnp.float32),
    )
