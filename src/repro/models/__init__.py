"""LM substrate: layers, mixers (GQA/MLA/Mamba/xLSTM), MoE, stacks, Model."""

from .model import Model
