"""optim subpackage."""
