"""Optimizers: AdamW and Adafactor (factored second moment), pure JAX.

States mirror the parameter pytree leaf-for-leaf so the sharding rules
that place parameters also place optimizer state (ZeRO-3 via GSPMD).
Adafactor is used for the largest assigned archs: ~6 bytes/param total
(fp32 master + factored v + bf16 grads) keeps 671B trainable on 512
v5e chips (see DESIGN.md §4 and EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["make_optimizer", "warmup_cosine", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        wu = peak * (step + 1) / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, wu, peak * cos)

    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads), gn


def make_optimizer(
    kind: str,
    lr: Callable[[jax.Array], jax.Array],
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip: float = 1.0,
) -> Optimizer:
    if kind == "adamw":
        return _adamw(lr, b1, b2, eps, weight_decay, grad_clip)
    if kind == "adafactor":
        return _adafactor(lr, b2, eps, weight_decay, grad_clip)
    raise ValueError(kind)


def _adamw(lr, b1, b2, eps, wd, clip):
    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
            "gnorm": jnp.zeros((), jnp.float32),
        }

    def update(grads, state, params, step):
        grads, gn = clip_by_global_norm(grads, clip)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1 - b1**t
        bc2 = 1 - b2**t
        lr_t = lr(step)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * gf * gf
            upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
            pf = p.astype(jnp.float32)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                pf = pf * (1 - lr_t * wd)
            return (pf - lr_t * upd).astype(p.dtype), m2, v2

        out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v, "gnorm": gn}

    return Optimizer(init, update)


def _adafactor(lr, b2, eps, wd, clip):
    """Factored second moment for >=2D leaves (row/col statistics over the
    last two dims); no first moment — the memory-optimal configuration."""

    def init(params):
        def st(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return {
            "f": jax.tree_util.tree_map(st, params),
            "gnorm": jnp.zeros((), jnp.float32),
        }

    def update(grads, state, params, step):
        grads, gn = clip_by_global_norm(grads, clip)
        t = step.astype(jnp.float32) + 1.0
        beta2t = 1.0 - t**-0.8  # Adafactor's decaying beta2
        lr_t = lr(step)

        def upd(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + 1e-30
            if p.ndim >= 2:
                vr = beta2t * s["vr"] + (1 - beta2t) * g2.mean(-1)
                vc = beta2t * s["vc"] + (1 - beta2t) * g2.mean(-2)
                r = vr / jnp.maximum(vr.mean(-1, keepdims=True), 1e-30)
                vhat = r[..., None] * vc[..., None, :]
                new_s = {"vr": vr, "vc": vc}
            else:
                vhat = beta2t * s["v"] + (1 - beta2t) * g2
                new_s = {"v": vhat}
            u = gf * jax.lax.rsqrt(vhat + eps)
            # relative update clipping (Adafactor d=1.0)
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u)
            pf = p.astype(jnp.float32)
            if p.ndim >= 2:
                pf = pf * (1 - lr_t * wd)
            return (pf - lr_t * u).astype(p.dtype), new_s

        flat, td = jax.tree_util.tree_flatten(params)
        gflat = td.flatten_up_to(grads)
        sflat = td.flatten_up_to(state["f"])
        outs = [upd(g, s, p) for g, s, p in zip(gflat, sflat, flat)]
        new_p = td.unflatten([o[0] for o in outs])
        new_f = td.unflatten([o[1] for o in outs])
        return new_p, {"f": new_f, "gnorm": gn}

    return Optimizer(init, update)
