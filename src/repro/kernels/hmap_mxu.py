"""Batched H-map coordinates on the MXU — paper §7.1 (Eq. 32) on TPU.

The paper sketches computing many block coordinates per Tensor-Core MMA
by laying the map's constants in A, per-block inputs in B, and
thread-local offsets in C:  D = A x B + C.

On TPU the analogue unit is the MXU (128x128 systolic array).  The
H map (Eq. 16) is affine in (wx, wy, q*b):

    x = rho * (wx + 1*qb),   y = rho * (wy + 2*qb)

so with  A = rho * [[1, 0, 1], [0, 1, 2]]  (padded to an (8, 8) tile) and
B = [wx; wy; qb] for 128 blocks per step (padded to (8, 128)), one MXU
pass emits 128 block origins in element space; C adds the intra-block
(thread-local) offsets.  q*b itself is one shift-free integer multiply
after the bit-smear for b — scalar-unit work, exactly as on the GPU.

This kernel exists to make §7.1 concrete in TPU tile shapes; the
practical schedules use the index_map forms (the MXU variant is useful
when coordinates must be *materialized*, e.g. for gather/scatter lists).
All arithmetic is exact in f32 for coordinates < 2^24.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.hmap import pow2_floor

from .engine import pallas_launch
from .policy import resolve_interpret

__all__ = ["hmap2_coords_mxu"]


def hmap2_coords_mxu(
    wxy: jax.Array, rho: int = 1, interpret: bool | None = None
) -> jax.Array:
    """(T, 2) int32 grid coords -> (T, 2) int32 data-space element origins.

    Implements D = A x B + C (Eq. 32) with one (8,8)x(8,128) MXU matmul
    per 128 blocks.  C carries the intra-block offset of thread (0, 0)
    (zero here; real kernels add the full lane pattern).  ``interpret``
    resolves through ``policy.default_interpret()`` when None.
    """
    interpret = resolve_interpret(interpret)
    t = wxy.shape[0]
    assert wxy.shape == (t, 2) and t % 128 == 0

    a_host = np.zeros((8, 8), np.float32)
    a_host[0, 0] = rho  # x <- wx
    a_host[0, 2] = rho  # x <- qb
    a_host[1, 1] = rho  # y <- wy
    a_host[1, 2] = 2 * rho  # y <- 2 qb

    def kernel(w_ref, a_ref, o_ref):
        wx = w_ref[:, 0]
        wy = w_ref[:, 1]
        b = pow2_floor(jnp.maximum(wy, 1))
        qb = (wx // b) * b
        bmat = jnp.zeros((8, 128), jnp.float32)
        bmat = bmat.at[0].set(wx.astype(jnp.float32))
        bmat = bmat.at[1].set(wy.astype(jnp.float32))
        bmat = bmat.at[2].set(qb.astype(jnp.float32))
        d = jax.lax.dot_general(
            a_ref[...],
            bmat,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (8, 128); rows 0,1 are x,y
        o_ref[:, 0] = d[0].astype(jnp.int32)
        o_ref[:, 1] = d[1].astype(jnp.int32)

    return pallas_launch(
        kernel,
        out_shape=jax.ShapeDtypeStruct((t, 2), jnp.int32),
        grid=(t // 128,),
        in_specs=[
            pl.BlockSpec((128, 2), lambda i: (i, 0)),
            pl.BlockSpec((8, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((128, 2), lambda i: (i, 0)),
        interpret=interpret,
    )(wxy, jnp.asarray(a_host))
