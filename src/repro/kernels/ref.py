"""Pure-jnp oracles for every kernel in this package.

Each function is the semantic ground truth the Pallas kernels are
validated against (tests/test_kernels_*.py sweep shapes and dtypes and
``assert_allclose`` kernel vs oracle on the simplex domain).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "tril_mask",
    "accum2d",
    "edm2d",
    "ca2d_step",
    "tetra_mask",
    "accum3d",
    "ca3d_step",
    "causal_attention",
    "map_table_2d",
]


def tril_mask(n: int, dtype=jnp.bool_):
    """Inclusive lower-triangle mask {col <= row} of an n x n grid."""
    r = jnp.arange(n)
    return (r[None, :] <= r[:, None]).astype(dtype)


def map_table_2d(n_blocks: int, kind: str):
    """Oracle for the MAP test: the (x, y[, valid]) table each schedule
    should produce, computed with the host-side core library."""
    from repro.core.schedule import SimplexSchedule

    return SimplexSchedule(2, n_blocks, kind).table()


def accum2d(x: jax.Array) -> jax.Array:
    """ACCUM test oracle: +1 on every element of the inclusive lower
    triangle; elements above the diagonal are zeroed (out of domain)."""
    n = x.shape[0]
    m = tril_mask(n, x.dtype)
    return (x + 1) * m


def edm2d(p: jax.Array) -> jax.Array:
    """EDM test oracle: out[i, j] = ||p_i - p_j||_2 for j <= i, else 0."""
    d2 = jnp.sum((p[:, None, :] - p[None, :, :]) ** 2, axis=-1)
    d = jnp.sqrt(d2.astype(jnp.float32)).astype(p.dtype)
    return d * tril_mask(p.shape[0], p.dtype)


def ca2d_step(state: jax.Array) -> jax.Array:
    """Game-of-life step on the inclusive lower triangle with periodic
    wrap on the underlying square (paper §5.1: periodic boundaries for
    the 2-simplex; cells outside the triangle are permanently dead)."""
    n = state.shape[0]
    m = tril_mask(n, state.dtype)
    s = state * m
    neigh = jnp.zeros_like(s)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            if dx == 0 and dy == 0:
                continue
            neigh = neigh + jnp.roll(s, (dy, dx), axis=(0, 1))
    born = (s == 0) & (neigh == 3)
    survive = (s == 1) & ((neigh == 2) | (neigh == 3))
    return ((born | survive).astype(state.dtype)) * m


def tetra_mask(n: int, dtype=jnp.bool_):
    """T(n) = {x+y+z < n} mask of an n^3 grid, axes (z, y, x)."""
    r = jnp.arange(n)
    s = r[:, None, None] + r[None, :, None] + r[None, None, :]
    return (s < n).astype(dtype)


def accum3d(x: jax.Array) -> jax.Array:
    n = x.shape[0]
    m = tetra_mask(n, x.dtype)
    return (x + 1) * m


def ca3d_step(state: jax.Array) -> jax.Array:
    """Game-of-life (26-neighbour, B3/S23 analogue) on T(n) with free
    boundaries (paper §5.1: fixed dead cells outside the tetrahedron)."""
    n = state.shape[0]
    m = tetra_mask(n, state.dtype)
    s = state * m
    pad = jnp.pad(s, 1)
    neigh = jnp.zeros_like(s)
    for dz in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                if dx == dy == dz == 0:
                    continue
                neigh = neigh + pad[
                    1 + dz : 1 + dz + n, 1 + dy : 1 + dy + n, 1 + dx : 1 + dx + n
                ]
    born = (s == 0) & (neigh == 3)
    survive = (s == 1) & ((neigh == 2) | (neigh == 3))
    return ((born | survive).astype(state.dtype)) * m


def causal_attention(q, k, v, scale: float | None = None):
    """Reference causal attention (GQA aware).

    q: (B, Hq, S, D), k/v: (B, Hkv, S, D) with Hq % Hkv == 0.
    Softmax in f32; output in q.dtype.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vv.dtype), vv).astype(q.dtype)
