"""Pure-jnp oracles for every kernel in this package.

Each function is the semantic ground truth the Pallas kernels are
validated against (tests/test_kernels_*.py sweep shapes and dtypes and
``assert_allclose`` kernel vs oracle on the simplex domain).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "tril_mask",
    "accum2d",
    "edm2d",
    "ca2d_step",
    "tetra_mask",
    "accum3d",
    "ca3d_step",
    "simplex_mask",
    "accum_md",
    "edm3d",
    "edm_md",
    "ca_md_step",
    "causal_attention",
    "map_table_2d",
]


def tril_mask(n: int, dtype=jnp.bool_):
    """Inclusive lower-triangle mask {col <= row} of an n x n grid."""
    r = jnp.arange(n)
    return (r[None, :] <= r[:, None]).astype(dtype)


def map_table_2d(n_blocks: int, kind: str):
    """Oracle for the MAP test: the (x, y[, valid]) table each schedule
    should produce, computed with the host-side core library."""
    from repro.core.schedule import SimplexSchedule

    return SimplexSchedule(2, n_blocks, kind).table()


def accum2d(x: jax.Array) -> jax.Array:
    """ACCUM test oracle: +1 on every element of the inclusive lower
    triangle; elements above the diagonal are zeroed (out of domain)."""
    n = x.shape[0]
    m = tril_mask(n, x.dtype)
    return (x + 1) * m


def edm2d(p: jax.Array) -> jax.Array:
    """EDM test oracle: out[i, j] = ||p_i - p_j||_2 for j <= i, else 0."""
    d2 = jnp.sum((p[:, None, :] - p[None, :, :]) ** 2, axis=-1)
    d = jnp.sqrt(d2.astype(jnp.float32)).astype(p.dtype)
    return d * tril_mask(p.shape[0], p.dtype)


def ca2d_step(state: jax.Array) -> jax.Array:
    """Game-of-life step on the inclusive lower triangle with periodic
    wrap on the underlying square (paper §5.1: periodic boundaries for
    the 2-simplex; cells outside the triangle are permanently dead)."""
    n = state.shape[0]
    m = tril_mask(n, state.dtype)
    s = state * m
    neigh = jnp.zeros_like(s)
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            if dx == 0 and dy == 0:
                continue
            neigh = neigh + jnp.roll(s, (dy, dx), axis=(0, 1))
    born = (s == 0) & (neigh == 3)
    survive = (s == 1) & ((neigh == 2) | (neigh == 3))
    return ((born | survive).astype(state.dtype)) * m


def tetra_mask(n: int, dtype=jnp.bool_):
    """T(n) = {x+y+z < n} mask of an n^3 grid, axes (z, y, x)."""
    r = jnp.arange(n)
    s = r[:, None, None] + r[None, :, None] + r[None, None, :]
    return (s < n).astype(dtype)


def accum3d(x: jax.Array) -> jax.Array:
    n = x.shape[0]
    m = tetra_mask(n, x.dtype)
    return (x + 1) * m


def ca3d_step(state: jax.Array) -> jax.Array:
    """Game-of-life (26-neighbour, B3/S23 analogue) on T(n) with free
    boundaries (paper §5.1: fixed dead cells outside the tetrahedron)."""
    n = state.shape[0]
    m = tetra_mask(n, state.dtype)
    s = state * m
    pad = jnp.pad(s, 1)
    neigh = jnp.zeros_like(s)
    for dz in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                if dx == dy == dz == 0:
                    continue
                neigh = neigh + pad[
                    1 + dz : 1 + dz + n, 1 + dy : 1 + dy + n, 1 + dx : 1 + dx + n
                ]
    born = (s == 0) & (neigh == 3)
    survive = (s == 1) & ((neigh == 2) | (neigh == 3))
    return ((born | survive).astype(state.dtype)) * m


def simplex_mask(m: int, n: int, dtype=jnp.bool_):
    """The m-simplex domain mask in array-axis order.

    m=2 is the paper's inclusive lower triangle {col <= row}; m >= 3 is
    the strict simplex {sum(coords) < n} (``tetra_mask`` at m=3).  The
    per-cell sum is symmetric in the coordinates, so math order vs
    array-axis order is immaterial for m >= 3.
    """
    if m == 2:
        return tril_mask(n, dtype)
    r = jnp.arange(n)
    s = jnp.zeros((n,) * m, jnp.int32)
    for ax in range(m):
        shape = [1] * m
        shape[ax] = n
        s = s + r.reshape(shape)
    return (s < n).astype(dtype)


def accum_md(x: jax.Array) -> jax.Array:
    """General-m ACCUM oracle (m = x.ndim): +1 on the simplex, 0 off it
    (``accum2d``/``accum3d`` are the m=2/m=3 instances)."""
    m = x.ndim
    n = x.shape[0]
    return (x + 1) * simplex_mask(m, n, x.dtype)


def edm_md(p: jax.Array, m: int) -> jax.Array:
    """General-m EDM oracle: per-cell sum of pairwise point distances.

    ``out[c] = sum_{a < b} ||p[c_a] - p[c_b]||`` over the m coordinates
    of each simplex cell, 0 off the domain.  At m=2 this is exactly
    ``edm2d`` (a single pair); at m=3 each cell holds the perimeter of
    the triangle (p[i], p[j], p[k]).  The pair sum is symmetric under
    any coordinate permutation, so axis order is immaterial.
    """
    n = p.shape[0]
    d2 = jnp.sum((p[:, None, :] - p[None, :, :]) ** 2, axis=-1)
    d = jnp.sqrt(d2.astype(jnp.float32))
    out = jnp.zeros((n,) * m, jnp.float32)
    for i in range(m):
        for j in range(i + 1, m):
            rest = tuple(k for k in range(m) if k not in (i, j))
            out = out + jnp.expand_dims(d, rest)
    msk = simplex_mask(m, n, jnp.float32)
    return (out * msk).astype(p.dtype)


def edm3d(p: jax.Array) -> jax.Array:
    """EDM3D oracle — per-cell triangle perimeter on T(n)."""
    return edm_md(p, 3)


def ca_md_step(state: jax.Array) -> jax.Array:
    """General-m CA oracle (m = state.ndim >= 3): one (3^m - 1)-neighbour
    B3/S23 step on the simplex with free boundaries (``ca3d_step`` is
    the m=3 instance; the 2-simplex wraps — use ``ca2d_step``)."""
    m = state.ndim
    assert m >= 3, "the 2-simplex CA is periodic — use ca2d_step"
    n = state.shape[0]
    msk = simplex_mask(m, n, state.dtype)
    s = state * msk
    pad = jnp.pad(s, 1)
    neigh = jnp.zeros_like(s)
    for shift in _offsets(m):
        if all(d == 0 for d in shift):
            continue
        sl = tuple(slice(1 + d, 1 + d + n) for d in shift)
        neigh = neigh + pad[sl]
    born = (s == 0) & (neigh == 3)
    survive = (s == 1) & ((neigh == 2) | (neigh == 3))
    return ((born | survive).astype(state.dtype)) * msk


def _offsets(m: int):
    """All 3^m offset vectors in {-1, 0, 1}^m."""
    import itertools

    return itertools.product((-1, 0, 1), repeat=m)


def causal_attention(q, k, v, scale: float | None = None):
    """Reference causal attention (GQA aware).

    q: (B, Hq, S, D), k/v: (B, Hkv, S, D) with Hq % Hkv == 0.
    Softmax in f32; output in q.dtype.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (d**0.5)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(vv.dtype), vv).astype(q.dtype)
