"""Deprecated per-(body, dimension) kernel entry points.

.. deprecated::
    Every function here is a thin shim over the dimension-generic
    ``SimplexKernel`` engine (``kernels/engine.py``, DESIGN.md §2.3),
    kept so existing imports keep working — each call emits a
    ``DeprecationWarning`` and delegates to the engine:

    ========================  =======================================
    legacy entry point        engine replacement
    ========================  =======================================
    ``map2d(nb, ...)``        ``engine.map_table(nb, m=2, ...)``
    ``accum2d(x, ...)``       ``engine.accum(x, ...)``
    ``edm2d(p, ...)``         ``engine.edm2d(p, ...)``
    ``ca2d(state, ...)``      ``engine.ca(state, ...)``
    ``accum3d(x, ...)``       ``engine.accum(x, ...)``
    ``ca3d(state, ...)``      ``engine.ca(state, ...)``
    ``accum_md(x, ...)``      ``engine.accum_md(x, ...)``
    ``grid_steps_2d(nb, k)``  ``engine.grid_steps(nb, k, m=2)``
    ``grid_steps_3d(nb, k)``  ``engine.grid_steps(nb, k, m=3)``
    ========================  =======================================

    Signatures, defaults, and outputs are unchanged (the differential
    suite ``tests/test_engine_parity.py`` pins engine-vs-legacy parity
    bit for bit against the frozen originals in ``kernels/legacy.py``).
    One behavioral *extension*: the engine serves linear-grid kinds
    (``table`` / ``composite``) at m=2 too, so the old "2D kernels
    launch a (w, h) grid" ``ValueError`` is gone.

New workloads should register a body with the engine instead of adding
functions here (see ``engine.register_body`` / DESIGN.md §2.3).
"""

from __future__ import annotations

import warnings

import jax

from . import engine

__all__ = [
    "map2d",
    "accum2d",
    "edm2d",
    "ca2d",
    "accum3d",
    "ca3d",
    "accum_md",
    "grid_steps_2d",
    "grid_steps_3d",
]


def _warn(old: str, new: str) -> None:
    warnings.warn(
        f"repro.kernels.simplex_kernels.{old} is deprecated; use "
        f"repro.kernels.engine.{new} (the dimension-generic SimplexKernel "
        "engine) instead",
        DeprecationWarning,
        stacklevel=3,
    )


def map2d(
    nb: int, kind: str = "hmap", chunk: int = 128, interpret: bool | None = None
) -> jax.Array:
    """Deprecated: ``engine.map_table(nb, m=2, ...)`` — (steps, 3) int32
    (x, y, valid) rows of the 2-simplex schedule walk."""
    _warn("map2d", "map_table")
    return engine.map_table(nb, m=2, kind=kind, chunk=chunk, interpret=interpret)


def accum2d(
    x: jax.Array,
    rho: int = 8,
    kind: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """Deprecated: ``engine.accum(x, ...)`` — +1 on the inclusive lower
    triangle of x (n x n, rho | n), in-place semantics via aliasing."""
    _warn("accum2d", "accum")
    return engine.accum(x, rho=rho, kind=kind, interpret=interpret)


def edm2d(
    p: jax.Array,
    rho: int = 8,
    kind: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """Deprecated: ``engine.edm2d(p, ...)`` — ||p_i - p_j|| on the
    inclusive lower triangle, 0 elsewhere."""
    _warn("edm2d", "edm2d")
    return engine.edm2d(p, rho=rho, kind=kind, interpret=interpret)


def ca2d(
    state: jax.Array,
    rho: int = 8,
    kind: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """Deprecated: ``engine.ca(state, ...)`` — one GoL step on the
    inclusive lower triangle (periodic underlying square)."""
    _warn("ca2d", "ca")
    return engine.ca(state, rho=rho, kind=kind, interpret=interpret)


def accum3d(
    x: jax.Array,
    rho: int = 4,
    kind: str = "auto",
    interpret: bool | None = None,
    split: bool | None = None,
) -> jax.Array:
    """Deprecated: ``engine.accum(x, ...)`` — +1 on T(n) = {x+y+z < n};
    axes (z, y, x); rho | n."""
    _warn("accum3d", "accum")
    return engine.accum(x, rho=rho, kind=kind, interpret=interpret, split=split)


def ca3d(
    state: jax.Array,
    rho: int = 4,
    kind: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """Deprecated: ``engine.ca(state, ...)`` — one 26-neighbour GoL step
    on T(n), free boundaries."""
    _warn("ca3d", "ca")
    return engine.ca(state, rho=rho, kind=kind, interpret=interpret)


def accum_md(
    x: jax.Array,
    rho: int = 2,
    kind: str = "auto",
    interpret: bool | None = None,
    split: bool | None = None,
) -> jax.Array:
    """Deprecated: ``engine.accum_md(x, ...)`` — +1 on T(n) =
    {sum(coords) < n} for an m-cube input (m = x.ndim >= 3)."""
    _warn("accum_md", "accum_md")
    return engine.accum_md(
        x, rho=rho, kind=kind, interpret=interpret, split=split
    )


def grid_steps_2d(nb: int, kind: str) -> int:
    """Deprecated: ``engine.grid_steps(nb, kind, m=2)``."""
    _warn("grid_steps_2d", "grid_steps")
    return engine.grid_steps(nb, kind, m=2)


def grid_steps_3d(nb: int, kind: str) -> int:
    """Deprecated: ``engine.grid_steps(nb, kind, m=3)``."""
    _warn("grid_steps_3d", "grid_steps")
    return engine.grid_steps(nb, kind, m=3)
