"""Execution policy for every Pallas launch in the kernels package.

Historically each ``pl.pallas_call`` in this repo pinned
``interpret=True`` — correct everywhere, but it means every benchmark
number measured the Pallas *interpreter* (a Python loop per grid step),
not compiled device code.  This module is the single place that decides
how a kernel executes:

* ``default_interpret()`` — the per-backend default: the CPU backend can
  only interpret (Mosaic/Triton lowering raises ``"Only interpret mode
  is supported on CPU backend"``), so CPU resolves to ``True``;
  TPU/GPU resolve to ``False`` — the real compiled index_map path.
* ``REPRO_INTERPRET`` env var — explicit override for tests and debug:
  ``1`` forces the old always-interpret behavior, ``0`` forces the
  compiled path even on CPU (useful only to reproduce the lowering
  error; the supported compiled path on CPU is the fused-XLA executor
  in ``kernels/compiled.py``).
* ``check_tile_alignment`` — the 8x128 tiling contract the compiled
  (Mosaic) path imposes on block shapes; interpret mode accepts any
  shape, so kernels call this only when actually compiling.

Every kernel entry point takes ``interpret: bool | None = None`` and
resolves ``None`` through ``resolve_interpret`` at call time — no
``pallas_call`` site hardcodes a mode anymore.
"""

from __future__ import annotations

import os
from typing import Optional, Sequence

__all__ = [
    "default_interpret",
    "resolve_interpret",
    "backend_name",
    "check_tile_alignment",
    "tile_alignment_ok",
    "aligned_rho",
    "TPU_SUBLANE",
    "TPU_LANE",
]

# Mosaic tiling contract for f32/int32 blocks: (sublane, lane) minimums.
TPU_SUBLANE = 8
TPU_LANE = 128

_ENV = "REPRO_INTERPRET"
_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


def backend_name(backend: Optional[str] = None) -> str:
    """The effective JAX backend name ('cpu', 'tpu', 'gpu', ...).

    Args:
        backend: Explicit backend name, or None to ask JAX.

    Returns:
        Lower-cased backend platform name.
    """
    if backend is not None:
        return backend.lower()
    import jax

    return jax.default_backend().lower()


def default_interpret(backend: Optional[str] = None) -> bool:
    """Per-backend interpret default for every ``pallas_call`` site.

    Resolution order:

    1. ``REPRO_INTERPRET`` env var (``1``/``true`` -> interpret,
       ``0``/``false`` -> compiled) — the test/debug escape hatch.
    2. Backend capability: CPU supports only interpret mode, so it
       resolves ``True``; TPU/GPU resolve ``False`` (compiled).

    Args:
        backend: Backend name override; defaults to the active JAX
            backend.

    Returns:
        True when kernels should run under the Pallas interpreter.
    """
    env = os.environ.get(_ENV, "").strip().lower()
    if env in _TRUE:
        return True
    if env in _FALSE:
        return False
    return backend_name(backend) == "cpu"


def resolve_interpret(
    interpret: Optional[bool] = None, backend: Optional[str] = None
) -> bool:
    """Resolve a kernel's ``interpret`` argument (None -> policy default).

    Args:
        interpret: Caller-requested mode, or None for the policy.
        backend: Backend name override for the default resolution.

    Returns:
        The concrete bool to pass to ``pl.pallas_call``.
    """
    if interpret is None:
        return default_interpret(backend)
    return bool(interpret)


def check_tile_alignment(
    block_shape: Sequence[int], interpret: bool, what: str = "block"
) -> None:
    """Enforce the Mosaic 8x128 tiling contract on compiled launches.

    Interpret mode accepts any block shape (tests use tiny rho); the
    compiled path requires the last dimension to be a multiple of 128
    (lane) and the second-to-last a multiple of 8 (sublane for f32/i32).
    Raises ``ValueError`` with the offending shape instead of letting
    Mosaic fail deep inside lowering.

    Args:
        block_shape: The BlockSpec block shape about to be launched.
        interpret: Resolved interpret mode; no-op when True.
        what: Label used in the error message.
    """
    if interpret or len(block_shape) == 0:
        return
    dims = [int(d) for d in block_shape if int(d) != 1]
    if not dims:
        return
    lane = dims[-1]
    if lane % TPU_LANE != 0:
        raise ValueError(
            f"compiled (non-interpret) Pallas requires the {what} minor "
            f"dimension to be a multiple of {TPU_LANE}; got {tuple(block_shape)}. "
            f"Use aligned_rho() or run with interpret=True/REPRO_INTERPRET=1."
        )
    if len(dims) >= 2 and dims[-2] % TPU_SUBLANE != 0:
        raise ValueError(
            f"compiled (non-interpret) Pallas requires the {what} sublane "
            f"dimension to be a multiple of {TPU_SUBLANE}; got "
            f"{tuple(block_shape)}."
        )


def tile_alignment_ok(block_shape: Sequence[int]) -> bool:
    """Non-raising form of the compiled-path tiling contract.

    The static-analysis tile pass (``repro.analysis``, DESIGN.md §9)
    asks this instead of catching ``check_tile_alignment``'s
    ``ValueError`` — same rule, boolean answer.

    Args:
        block_shape: Candidate BlockSpec block shape.

    Returns:
        True when a compiled (non-interpret) launch would accept it.
    """
    try:
        check_tile_alignment(block_shape, interpret=False)
    except ValueError:
        return False
    return True


def aligned_rho(rho: int, interpret: Optional[bool] = None) -> int:
    """Round a square tile size up to the compiled-path alignment.

    Under interpret mode the requested rho is returned unchanged; on the
    compiled path rho is rounded up to the lane width (128) so a
    (rho, rho) block satisfies both the sublane and lane constraints.

    Args:
        rho: Requested square tile side.
        interpret: Resolved or requested mode (None -> policy default).

    Returns:
        A rho every compiled BlockSpec accepts.
    """
    if resolve_interpret(interpret):
        return rho
    return ((rho + TPU_LANE - 1) // TPU_LANE) * TPU_LANE
