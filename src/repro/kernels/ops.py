"""Public jit'd wrappers for the kernels package.

These are the entry points the rest of the framework (and users) call;
each selects a schedule (`kind`), jit-compiles, and for non-simplex
backends falls back to the pure-jnp reference implementation so models
run identically on hosts without Pallas support.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .hmap_mxu import hmap2_coords_mxu
from .simplex_kernels import (
    accum2d,
    accum3d,
    accum_md,
    ca2d,
    ca3d,
    edm2d,
    map2d,
)

__all__ = [
    "simplex_accum2d",
    "simplex_edm2d",
    "simplex_ca2d",
    "simplex_accum3d",
    "simplex_ca3d",
    "simplex_accum_md",
    "causal_flash_attention",
    "hmap_coords_mxu",
    "map_table",
]


@functools.partial(jax.jit, static_argnames=("rho", "kind"))
def simplex_accum2d(x, rho: int = 8, kind: str = "hmap"):
    return accum2d(x, rho=rho, kind=kind)


@functools.partial(jax.jit, static_argnames=("rho", "kind"))
def simplex_edm2d(p, rho: int = 8, kind: str = "hmap"):
    return edm2d(p, rho=rho, kind=kind)


@functools.partial(jax.jit, static_argnames=("rho", "kind"))
def simplex_ca2d(state, rho: int = 8, kind: str = "hmap"):
    return ca2d(state, rho=rho, kind=kind)


@functools.partial(jax.jit, static_argnames=("rho", "kind"))
def simplex_accum3d(x, rho: int = 4, kind: str = "table"):
    return accum3d(x, rho=rho, kind=kind)


@functools.partial(jax.jit, static_argnames=("rho", "kind"))
def simplex_ca3d(state, rho: int = 4, kind: str = "table"):
    return ca3d(state, rho=rho, kind=kind)


@functools.partial(jax.jit, static_argnames=("rho", "kind"))
def simplex_accum_md(x, rho: int = 2, kind: str = "table"):
    """General-m accumulate; m = x.ndim (DESIGN.md §4)."""
    return accum_md(x, rho=rho, kind=kind)


@functools.partial(
    jax.jit, static_argnames=("kind", "block_q", "block_kv", "impl")
)
def causal_flash_attention(
    q, k, v, kind: str = "folded", block_q: int = 128, block_kv: int = 128,
    impl: str = "pallas",
):
    """Causal GQA attention.  impl='pallas' uses the simplex-grid kernel
    (interpret mode off-TPU); impl='xla' is the fused-XLA reference path
    used by the distributed dry-run (Pallas TPU kernels cannot lower on
    the CPU backend — DESIGN.md §8)."""
    if impl == "xla":
        return ref.causal_attention(q, k, v)
    return flash_attention(q, k, v, kind=kind, block_q=block_q, block_kv=block_kv)


@functools.partial(jax.jit, static_argnames=("rho",))
def hmap_coords_mxu(wxy, rho: int = 1):
    return hmap2_coords_mxu(wxy, rho=rho)


def map_table(nb: int, kind: str = "hmap"):
    """The MAP test's output: (steps, 3) coordinate table."""
    return map2d(nb, kind)
