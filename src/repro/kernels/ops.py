"""Public jit'd wrappers for the kernels package.

These are the entry points the rest of the framework (and users) call;
each resolves a schedule (``kind='auto'`` asks the ``repro.autotune``
subsystem — kernels never hand-pick a schedule), jit-compiles, and for
non-simplex backends falls back to the pure-jnp reference implementation
so models run identically on hosts without Pallas support.

Execution policy lives in ``kernels/policy.py`` and is re-exported here:
``default_interpret()`` resolves the per-backend Pallas mode (CPU can
only interpret; TPU/GPU compile the index_maps; ``REPRO_INTERPRET``
overrides).  The fused-XLA compiled executors — the compiled path that
works on every backend, including CPU — live in ``kernels/compiled.py``
and are exported here as ``simplex_accum*_compiled``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import engine, ref
from .compiled import (
    accum2d_compiled,
    accum3d_compiled,
    accum_md_compiled,
)
from .flash_attention import flash_attention
from .hmap_mxu import hmap2_coords_mxu
from .policy import default_interpret, resolve_interpret

__all__ = [
    "default_interpret",
    "resolve_interpret",
    "simplex_accum2d",
    "simplex_edm2d",
    "simplex_ca2d",
    "simplex_accum3d",
    "simplex_ca3d",
    "simplex_accum_md",
    "simplex_edm3d",
    "simplex_edm_md",
    "simplex_ca_md",
    "simplex_accum2d_compiled",
    "simplex_accum3d_compiled",
    "simplex_accum_md_compiled",
    "causal_flash_attention",
    "hmap_coords_mxu",
    "map_table",
]


@functools.partial(jax.jit, static_argnames=("rho", "kind", "interpret"))
def simplex_accum2d(x, rho: int = 8, kind: str = "auto", interpret=None):
    """+1 on the inclusive lower triangle (engine ACCUM body at m=2)."""
    return engine.accum(x, rho=rho, kind=kind, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("rho", "kind", "interpret"))
def simplex_edm2d(p, rho: int = 8, kind: str = "auto", interpret=None):
    """||p_i - p_j|| on the lower triangle (engine EDM body at m=2)."""
    return engine.edm2d(p, rho=rho, kind=kind, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("rho", "kind", "interpret"))
def simplex_ca2d(state, rho: int = 8, kind: str = "auto", interpret=None):
    """One periodic GoL step on the triangle (engine CA body at m=2)."""
    return engine.ca(state, rho=rho, kind=kind, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("rho", "kind", "interpret", "split")
)
def simplex_accum3d(
    x, rho: int = 4, kind: str = "auto", interpret=None, split=None
):
    """+1 on the 3-simplex T(n) (engine ACCUM body at m=3)."""
    return engine.accum(x, rho=rho, kind=kind, interpret=interpret, split=split)


@functools.partial(jax.jit, static_argnames=("rho", "kind", "interpret"))
def simplex_ca3d(state, rho: int = 4, kind: str = "auto", interpret=None):
    """One free-boundary GoL step on T(n) (engine CA body at m=3)."""
    return engine.ca(state, rho=rho, kind=kind, interpret=interpret)


@functools.partial(
    jax.jit, static_argnames=("rho", "kind", "interpret", "split")
)
def simplex_accum_md(
    x, rho: int = 2, kind: str = "auto", interpret=None, split=None
):
    """General-m accumulate; m = x.ndim (DESIGN.md §4)."""
    return engine.accum_md(
        x, rho=rho, kind=kind, interpret=interpret, split=split
    )


@functools.partial(
    jax.jit, static_argnames=("rho", "kind", "interpret", "split")
)
def simplex_edm3d(p, rho: int = 4, kind: str = "auto", interpret=None,
                  split=None):
    """Per-cell triangle perimeter on T(n) (engine EDM body at m=3)."""
    return engine.edm3d(p, rho=rho, kind=kind, interpret=interpret,
                        split=split)


@functools.partial(
    jax.jit, static_argnames=("m", "rho", "kind", "interpret", "split")
)
def simplex_edm_md(p, m: int, rho: int | None = None, kind: str = "auto",
                   interpret=None, split=None):
    """General-m EDM: out[c] = sum of pairwise distances of the cell's
    m points (engine EDM body; m >= 3 — use simplex_edm2d at m=2)."""
    return engine.edm_md(p, m, rho=rho, kind=kind, interpret=interpret,
                         split=split)


@functools.partial(jax.jit, static_argnames=("rho", "kind", "interpret"))
def simplex_ca_md(state, rho: int | None = None, kind: str = "auto",
                  interpret=None):
    """General-m CA: one (3^m - 1)-neighbour GoL step on T(n), free
    boundaries (engine CA body; m = state.ndim >= 3)."""
    return engine.ca_md(state, rho=rho, kind=kind, interpret=interpret)


# Fused-XLA compiled executors (kernels/compiled.py): the whole schedule
# walk is traced into ONE jit program — the compiled-numbers path on
# hosts whose Pallas backend can only interpret (DESIGN.md §5).
simplex_accum2d_compiled = accum2d_compiled
simplex_accum3d_compiled = accum3d_compiled
simplex_accum_md_compiled = accum_md_compiled


@functools.partial(
    jax.jit, static_argnames=("kind", "block_q", "block_kv", "impl", "interpret")
)
def causal_flash_attention(
    q, k, v, kind: str = "auto", block_q: int = 0, block_kv: int = 0,
    impl: str = "pallas", interpret=None,
):
    """Causal GQA attention through the policy-resolved flash kernel.

    kind='auto' resolves schedule AND tile through the cached
    ``autotune.choose_attn_impl(seq, heads, head_dim, backend)``
    decision (an auto-resolved 'chunked' runs the fused-XLA reference);
    kind='folded'/'bb' forces the schedule, with ``block_q``/``block_kv``
    passed straight through to the kernel (0 = let autotune pick the
    tile).  impl='pallas' launches the simplex-grid kernel with
    interpret mode resolved per backend (policy.default_interpret);
    impl='xla' forces the fused-XLA reference path used by the
    distributed dry-run (Pallas TPU kernels cannot lower on the CPU
    backend — DESIGN.md §5, §8)."""
    if impl == "xla":
        return ref.causal_attention(q, k, v)
    if kind == "auto" or block_q <= 0:
        from repro.autotune import choose_attn_impl

        b, hq, s, d = q.shape
        dec = choose_attn_impl(s, hq, d)
        if kind == "auto":
            if dec.impl != "flash" or dec.block_q <= 0:
                return ref.causal_attention(q, k, v)
            kind = dec.kind
        if block_q <= 0:
            if dec.block_q <= 0:
                return ref.causal_attention(q, k, v)
            block_q = block_kv = dec.block_q
    return flash_attention(
        q, k, v, kind=kind, block_q=block_q, block_kv=block_kv,
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("rho", "interpret"))
def hmap_coords_mxu(wxy, rho: int = 1, interpret=None):
    """MXU-path H-map: grid coords ``(w, x, y)`` -> block coords.

    Thin jit'd wrapper over ``hmap_mxu.hmap2_coords_mxu`` (the matrix-
    unit evaluation of the 2-simplex block map); ``interpret`` resolves
    through ``kernels/policy.py`` like every other entry point.
    """
    return hmap2_coords_mxu(wxy, rho=rho, interpret=interpret)


def map_table(nb: int, kind: str = "hmap", m: int = 2):
    """The MAP test's output: (steps, m+1) coordinate table.

    Example:
        >>> import numpy as np
        >>> np.asarray(map_table(2, kind="hmap")).shape  # tri(2) steps
        (3, 3)
    """
    return engine.map_table(nb, m=m, kind=kind)
