"""Pallas TPU kernels: simplex-test kernels (paper Table 1), the
simplex-grid causal flash attention, and the MXU batched map (§7.1).
Validated against ref.py oracles in interpret mode; ops.py holds the
public jit'd wrappers."""

from . import ops, ref
