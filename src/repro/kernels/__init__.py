"""Pallas TPU kernels for the paper's simplex workloads.

``engine.py`` is the dimension-generic ``SimplexKernel`` launcher
(body registry + 3^m halo subsystem, DESIGN.md §2.3); ``legacy.py``
freezes the original hand-rolled kernels as the differential-parity
baseline; ``simplex_kernels.py`` holds the deprecated shims over the
engine.  The simplex-grid causal flash attention and the MXU batched
map (§7.1) live beside them.  Everything is validated against the
``ref.py`` oracles in interpret mode; ``ops.py`` holds the public
jit'd wrappers.
"""

from . import engine, ops, ref
