"""The dimension-generic ``SimplexKernel`` engine (DESIGN.md §2.3).

One launcher serves every simplex workload at every dimension: a kernel
*body* (a small class declaring its halo stencil and per-tile compute —
MAP / ACCUM / EDM / CA ship here) is combined with any
``core.schedule.SimplexSchedule`` and lowered to a single
``pl.pallas_call`` per launch piece.  What used to be one hand-written
``pallas_call`` per (body, dimension) — EDM only at m=2, CA only at
m in {2, 3} — is now one generic construction, so the missing siblings
(``edm3d``, ``edm_md``, ``ca_md``) are O(1)-effort body registrations
rather than new kernels.

The engine owns every TPU-facing convention the hand-rolled kernels
established, dimension-generically:

* **Grid handling** — multi-axis grids (the m=2 ``(w, h)`` kinds) and
  linear grids (everything else) through the same index-map builder;
  table-driven kinds ship their payload via
  ``PrefetchScalarGridSpec``.
* **Trash tile** — the domain array is padded by one tile row along
  axis 0 and invalid grid steps park there, so Pallas' end-of-step
  block flush never clobbers live data; in-place semantics come from
  input/output aliasing of the body's *seed* array.
* **3^m halo subsystem** — bodies that declare ``halo = True`` receive
  a ``(3*rho,)*m`` neighborhood assembled from 3^m shifted input refs
  (the standard Pallas stencil pattern — no element-offset reads on
  TPU), each tile masked by the domain predicate of its own position.
  Boundary handling is per body and dimension: ``'periodic'`` wraps
  block coordinates mod nb (the 2-simplex CA convention), ``'free'``
  clamps reads at the domain edge and masks by true coordinates (the
  m >= 3 convention).
* **Execution policy** — ``interpret=None`` resolves through
  ``kernels/policy.py`` per backend; block shapes are checked against
  the Mosaic tiling contract before compiled launches.
* **Launch splitting** — ``kind='composite'`` schedules can launch one
  ``pallas_call`` per piece (``split=``, autotuned default); the engine
  refuses the split for halo bodies, whose neighbor reads make
  per-piece chaining unsound.
* **Explicit schedules** — ``SimplexKernel(..., schedule=s)`` launches
  any object with the schedule surface (``.grid``/``.map``/
  ``.prefetch``) instead of resolving a kind: the per-shard execution
  path of ``distributed/simplex_sharding.py`` (DESIGN.md §7), where
  each device walks one ``ShardSchedule`` of the folded partition.
* **Compiled fallback** — ``executor='xla'`` routes to the fused-XLA
  executors in ``kernels/compiled.py`` where one exists (ACCUM, MAP),
  the compiled path on hosts whose Pallas backend can only interpret.

Every ``pl.pallas_call`` in the package is constructed here or in
``kernels/compiled.py``; other modules launch through
``pallas_launch`` (enforced by an AST test in
``tests/test_compiled.py``).  The hand-rolled originals survive
verbatim in ``kernels/legacy.py`` as the differential baseline for
``tests/test_engine_parity.py``; the public entry points in
``kernels/simplex_kernels.py`` are deprecated shims over this module.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.schedule import SimplexSchedule, resolve_kind

from .policy import check_tile_alignment, resolve_interpret

__all__ = [
    "SimplexKernel",
    "KernelBody",
    "BodyContext",
    "register_body",
    "registered_bodies",
    "get_body",
    "pallas_launch",
    "domain_mask",
    "halo_shifts",
    "launch_shifts",
    "out_block_transform",
    "shift_block_transform",
    "map_table",
    "accum",
    "edm",
    "ca",
    "edm2d",
    "edm3d",
    "edm_md",
    "ca_md",
    "accum_md",
    "default_rho",
]


# ---------------------------------------------------------------------------
# the one pallas_call front door
# ---------------------------------------------------------------------------


def pallas_launch(kernel, *, interpret: Optional[bool] = None, **kwargs):
    """Construct a ``pl.pallas_call`` with the resolved execution policy.

    The single sanctioned way to launch Pallas outside this module and
    ``kernels/compiled.py`` (AST-enforced): ``interpret=None`` resolves
    through ``policy.resolve_interpret`` (CPU interprets, TPU/GPU
    compile, ``REPRO_INTERPRET`` overrides); all other keyword
    arguments pass through to ``pl.pallas_call`` unchanged.

    Args:
        kernel: The Pallas kernel function.
        interpret: Execution mode; ``None`` resolves per backend.
        **kwargs: Forwarded to ``pl.pallas_call``.

    Returns:
        The callable returned by ``pl.pallas_call``.
    """
    return pl.pallas_call(
        kernel, interpret=resolve_interpret(interpret), **kwargs
    )


# ---------------------------------------------------------------------------
# geometry helpers shared by every body
# ---------------------------------------------------------------------------


def default_rho(m: int) -> int:
    """The per-dimension default tile side the legacy kernels used.

    Args:
        m: Simplex dimension.

    Returns:
        8 at m=2, 4 at m=3, 2 at m >= 4 — small enough that the
        interpret-mode test sweeps stay fast, overridable everywhere.
    """
    return {2: 8, 3: 4}.get(m, 2)


def domain_mask(m: int, n: int, coords: Sequence) -> jax.Array:
    """The per-element domain predicate in array-axis order.

    Args:
        m: Simplex dimension.
        n: Side length in elements.
        coords: One global coordinate array per array axis (axis 0
            first — axis j holds math coordinate ``x_{m-1-j}``).

    Returns:
        Boolean mask: the m=2 inclusive lower triangle
        ``{col <= row}``, or the strict simplex ``{sum < n}`` at
        m >= 3 — the repo-wide domain conventions (DESIGN.md §2.2).
    """
    if m == 2:
        return coords[1] <= coords[0]
    total = coords[0]
    for c in coords[1:]:
        total = total + c
    return total < n


def halo_shifts(m: int) -> Tuple[Tuple[int, ...], ...]:
    """The full 3^m block-offset stencil at dimension m.

    Args:
        m: Simplex dimension.

    Returns:
        All ``(-1, 0, 1)^m`` offset tuples, lexicographic order —
        the neighborhood a ``halo = True`` body is assembled from.

    Example:
        >>> halo_shifts(2)[:3]
        ((-1, -1), (-1, 0), (-1, 1))
    """
    return tuple(itertools.product((-1, 0, 1), repeat=m))


def launch_shifts(body: "KernelBody", m: int) -> Tuple[Tuple[int, ...], ...]:
    """Block offsets the engine actually fetches for ``body`` at dim m.

    One shifted input ref is launched per offset: the full 3^m stencil
    for halo bodies, the centre alone otherwise.  The halo-conformance
    pass (``repro.analysis``, DESIGN.md §9) diffs this mechanism-side
    set against the body's *declared* ``stencil(m)``.

    Args:
        body: The kernel body.
        m: Simplex dimension.

    Returns:
        Offset tuples, centre ``(0,)*m`` always included.
    """
    return halo_shifts(m) if body.halo else ((0,) * m,)


def out_block_transform(nb: int) -> Callable:
    """The engine's output index-map transform: clip + trash-tile park.

    Valid grid steps write their (clipped) block; invalid steps park at
    the trash row ``nb`` appended along axis 0, so Pallas' end-of-step
    flush never clobbers live data.  Shared by ``_launch_domain`` and
    the write-race pass in ``repro.analysis`` so the analyzer verifies
    the exact transform the launcher uses (DESIGN.md §9).

    Args:
        nb: Tile count per side (trash row index along axis 0).

    Returns:
        ``transform(blocks, coords, valid) -> block index tuple`` in
        array-axis order.
    """

    def _t(blocks, coords, valid):
        clipped = tuple(jnp.clip(b, 0, nb - 1) for b in blocks)
        return (jnp.where(valid, clipped[0], nb),) + clipped[1:]

    return _t


def shift_block_transform(d: Tuple[int, ...], nb: int,
                          boundary: str) -> Callable:
    """The engine's input index-map transform for stencil offset ``d``.

    ``'periodic'`` wraps block coordinates mod nb (the 2-simplex CA
    convention); ``'free'`` clamps at the domain edge and parks invalid
    steps at the trash row (the m >= 3 convention) — clamp duplicates
    are masked inert by true coordinates at assembly time.

    Args:
        d: Block offset, one entry per array axis.
        nb: Tile count per side.
        boundary: ``'periodic'`` or ``'free'``.

    Returns:
        ``transform(blocks, coords, valid) -> block index tuple``.
    """

    def _t(blocks, coords, valid):
        if boundary == "periodic":
            return tuple((b + dj) % nb for b, dj in zip(blocks, d))
        shifted = tuple(
            jnp.clip(b + dj, 0, nb - 1) for b, dj in zip(blocks, d)
        )
        return (jnp.where(valid, shifted[0], nb),) + shifted[1:]

    return _t


def _axis_coords(blocks, rho: int, shape: Tuple[int, ...]):
    """Global element coordinates of a tile, one array per axis."""
    m = len(shape)
    return [
        blocks[j] * rho
        + jax.lax.broadcasted_iota(jnp.int32, shape, j)
        for j in range(m)
    ]


def _grid_spec(table, grid, in_specs, out_specs):
    """Plain grid or scalar-prefetch grid, matching the schedule kind."""
    if table is None:
        return (
            pl.GridSpec(grid=tuple(grid), in_specs=in_specs,
                        out_specs=out_specs),
            (),
        )
    from jax.experimental.pallas import tpu as pltpu

    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=tuple(grid),
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return spec, (jnp.asarray(table),)


def _schedule(m: int, nb: int, kind: str) -> SimplexSchedule:
    """Engine-facing schedule resolution (no m=2 grid-shape restriction:
    linear-grid kinds like ``composite`` are first-class at every m)."""
    return SimplexSchedule(m, nb, resolve_kind(m, nb, kind))


def _launch_plan(m: int, nb: int, kind: str, split: Optional[bool],
                 element_local: bool, schedule=None):
    """Schedules to launch, one ``pallas_call`` each (DESIGN.md §5).

    Composite schedules may split into one launch per piece when the
    body is element-local (pieces cover disjoint tiles, so chaining
    launches through the aliased output is exact); halo bodies always
    launch the fused walk — a split piece would read neighbours the
    previous launch already stepped.

    An explicit ``schedule`` (e.g. a ``ShardSchedule`` from
    ``distributed/simplex_sharding.py``, DESIGN.md §7) bypasses kind
    resolution and piece splitting: the engine launches exactly the
    steps that schedule enumerates.
    """
    if schedule is not None:
        if schedule.m != m or schedule.n != nb:
            raise ValueError(
                f"explicit schedule is (m={schedule.m}, nb={schedule.n}) "
                f"but the launch needs (m={m}, nb={nb})"
            )
        return [schedule]
    sched = _schedule(m, nb, kind)
    if sched.kind == "composite" and element_local:
        subs = sched.split_pieces()
        if split is None:
            from repro.autotune import should_split_pieces

            split = should_split_pieces(len(subs), sched.steps)
        if split and len(subs) > 1:
            return list(subs)
    return [sched]


def _make_index_map(fn: Callable, transform: Callable) -> Callable:
    """Wrap a schedule map into a ``BlockSpec.index_map``.

    ``fn`` is ``SimplexSchedule.map`` — ``(*w[, tab_ref]) ->
    (*coords, valid)`` with math-order coordinates; ``transform`` maps
    ``(blocks, coords, valid)`` (blocks in array-axis order) to the
    block index tuple Pallas should fetch.
    """

    def _index_map(*args):
        out = fn(*args)
        coords, valid = out[:-1], out[-1]
        return transform(tuple(coords[::-1]), coords, valid)

    return _index_map


# ---------------------------------------------------------------------------
# body contract
# ---------------------------------------------------------------------------


@dataclass
class BodyContext:
    """Everything a body's per-tile compute sees (the engine fills it).

    Attributes:
        m: Simplex dimension.
        n: Domain side length in elements.
        nb: Tile count per side.
        rho: Tile side length.
        dtype: Output dtype.
        blocks: Traced block coordinates in array-axis order.
        valid: Traced schedule validity flag for this grid step.
        center: Raw center tile of the seed array (``(rho,)*m``) — also
            the out-of-domain fallback value the engine writes.
        neighborhood: Masked ``(3*rho,)*m`` halo assembly, or None for
            bodies with ``halo = False``.
        extras: Tuple of refs for the body's extra operands.
    """

    m: int
    n: int
    nb: int
    rho: int
    dtype: object
    blocks: tuple
    valid: object
    center: object
    neighborhood: object
    extras: tuple

    def coords(self):
        """Global element coordinates of this tile, per array axis."""
        return _axis_coords(self.blocks, self.rho, (self.rho,) * self.m)

    def mask(self):
        """In-domain-and-valid element mask of this tile."""
        return domain_mask(self.m, self.n, self.coords()) & self.valid


class KernelBody:
    """Base class of the body-registration contract (DESIGN.md §2.3).

    A body declares *what* one tile computes; the engine owns *where*
    tiles live (schedule walk, trash tile, aliasing, halo assembly,
    execution policy).  Subclasses set the class attributes and
    implement ``tile``; bodies with non-tile outputs (MAP) override
    ``launch`` wholesale.

    Class attributes:
        name: Registry key.
        halo: True to receive the 3^m neighborhood in
            ``BodyContext.neighborhood``.
        element_local: True when per-piece launch splitting is sound
            (no cross-tile reads).
    """

    name: str = ""
    halo: bool = False
    element_local: bool = True

    # -- hooks ------------------------------------------------------------

    def boundary(self, m: int) -> str:
        """Halo boundary mode at dimension m: 'periodic' or 'free'."""
        return "periodic" if m == 2 else "free"

    def stencil(self, m: int) -> Tuple[Tuple[int, ...], ...]:
        """The block-offset stencil this body's compute declares it reads.

        Static-analysis metadata (DESIGN.md §9): the halo-conformance
        pass diffs this declaration against the blocks the engine's
        index maps actually fetch (``launch_shifts``).  The default is
        honest for the shipped bodies — full 3^m when ``halo`` is set,
        centre-only otherwise; a body whose ``tile`` reads fewer or
        more neighbours than the halo machinery supplies must override
        this so the declaration stays truthful.

        Args:
            m: Simplex dimension.

        Returns:
            Offset tuples, centre ``(0,)*m`` included.
        """
        return halo_shifts(m) if self.halo else ((0,) * m,)

    def seed(self, x, m: int):
        """(seed array, n): the domain-shaped array aliased to the
        output.  The default takes the operand itself (in-place
        semantics); EDM overrides with zeros."""
        n = x.shape[0]
        if x.shape != (n,) * m:
            raise ValueError(
                f"{self.name}: expected an m-cube operand of shape "
                f"{(n,) * m}, got {x.shape}"
            )
        return x, n

    def extra_arrays(self, x, m: int) -> tuple:
        """Extra operand arrays fetched per tile (default: none)."""
        return ()

    def extra_spec(self, a: int, x, m: int, nb: int, rho: int,
                   fn: Callable) -> pl.BlockSpec:
        """BlockSpec of extra operand ``a`` for the schedule map ``fn``."""
        raise NotImplementedError

    def tile(self, ctx: BodyContext):
        """The in-domain tile value (``(rho,)*m``); the engine writes
        ``where(ctx.mask(), tile, ctx.center)``."""
        raise NotImplementedError

    def launch(self, kernel: "SimplexKernel", x):
        """Run the body through the generic domain-array launcher."""
        return _launch_domain(kernel, self, x)

    def xla_executor(self, kernel: "SimplexKernel", x):
        """Fused-XLA fallback (``executor='xla'``); None if unavailable."""
        return None


# registry ------------------------------------------------------------------

_BODIES: Dict[str, KernelBody] = {}


def register_body(body: KernelBody) -> KernelBody:
    """Register a body instance under ``body.name``.

    Args:
        body: The ``KernelBody`` instance to register.

    Returns:
        The body, unchanged — usable as a decorator on instances.
    """
    _BODIES[body.name] = body
    return body


def registered_bodies() -> Tuple[str, ...]:
    """Sorted names of every registered body."""
    return tuple(sorted(_BODIES))


def get_body(body) -> KernelBody:
    """Resolve a body argument (name or instance) to the instance."""
    if isinstance(body, KernelBody):
        return body
    if body not in _BODIES:
        raise ValueError(
            f"no kernel body named {body!r}; registered: "
            f"{registered_bodies()}"
        )
    return _BODIES[body]


# ---------------------------------------------------------------------------
# the generic domain-array launcher
# ---------------------------------------------------------------------------


def _launch_domain(kernel: "SimplexKernel", body: KernelBody, x):
    """One launch per plan entry: seed/trash-tile padding, index maps,
    halo assembly, aliased output — the engine core."""
    m, rho = kernel.m, kernel.rho
    seed, n = body.seed(x, m)
    if n % rho != 0:
        raise ValueError(f"{body.name}: rho={rho} must divide n={n}")
    interpret = resolve_interpret(kernel.interpret)
    check_tile_alignment((rho,) * m, interpret)
    nb = n // rho
    extras = body.extra_arrays(x, m)

    shifts = list(launch_shifts(body, m))
    centre_idx = shifts.index((0,) * m)
    boundary = body.boundary(m)

    # trash tile appended along axis 0: invalid grid steps park there.
    padded = jnp.concatenate(
        [jnp.asarray(seed), jnp.zeros((rho,) + seed.shape[1:], seed.dtype)],
        axis=0,
    )
    dtype = padded.dtype

    for sched in _launch_plan(m, nb, kernel.kind, kernel.split,
                              body.element_local and not body.halo,
                              schedule=kernel.schedule):
        fn, table = sched.map, sched.prefetch
        _out_transform = out_block_transform(nb)

        in_specs = [
            pl.BlockSpec(
                (rho,) * m,
                _make_index_map(
                    fn,
                    _out_transform if d == (0,) * m
                    else shift_block_transform(d, nb, boundary),
                ),
            )
            for d in shifts
        ]
        in_specs += [
            body.extra_spec(a, x, m, nb, rho, fn)
            for a in range(len(extras))
        ]
        out_spec = pl.BlockSpec((rho,) * m, _make_index_map(fn, _out_transform))

        def _kernel_fn(*refs, fn=fn, table=table):
            if table is not None:
                pref = (refs[0],)
                refs = refs[1:]
            else:
                pref = ()
            halo_refs = refs[: len(shifts)]
            extra_refs = refs[len(shifts):-1]
            o_ref = refs[-1]
            ids = tuple(
                pl.program_id(i) for i in range(len(sched.grid))
            )
            out = fn(*ids, *pref)
            coords, valid = out[:-1], out[-1]
            blocks = tuple(coords[::-1])

            neighborhood = None
            if body.halo:
                neighborhood = _assemble_halo(
                    halo_refs, shifts, blocks, m, n, nb, rho,
                    boundary, dtype,
                )
            centre = halo_refs[centre_idx][...]
            ctx = BodyContext(
                m=m, n=n, nb=nb, rho=rho, dtype=dtype,
                blocks=blocks, valid=valid, center=centre,
                neighborhood=neighborhood, extras=tuple(extra_refs),
            )
            o_ref[...] = jnp.where(
                ctx.mask(), body.tile(ctx), centre
            ).astype(o_ref.dtype)

        grid_spec, args = _grid_spec(table, sched.grid, in_specs, out_spec)
        alias_src = len(args) + centre_idx
        padded = pallas_launch(
            _kernel_fn,
            interpret=interpret,
            out_shape=jax.ShapeDtypeStruct(padded.shape, dtype),
            grid_spec=grid_spec,
            input_output_aliases={alias_src: 0},
        )(*args, *([padded] * len(shifts)), *extras)
    return padded[:n]


def _assemble_halo(halo_refs, shifts, blocks, m, n, nb, rho, boundary,
                   dtype):
    """Build the masked ``(3*rho,)*m`` neighborhood of the current tile.

    Each of the 3^m shifted refs is masked by the domain predicate of
    ITS OWN position — wrapped coordinates under 'periodic' (matching
    the roll-of-masked-state reference semantics), true coordinates
    plus in-range checks under 'free' (clamp duplicates are inert) —
    then placed into the big array at its stencil offset.
    """
    big = jnp.zeros((3 * rho,) * m, dtype=dtype)
    shape = (rho,) * m
    for si, d in enumerate(shifts):
        t = halo_refs[si][...]
        if boundary == "periodic":
            tile_blocks = [
                (b + dj) % nb for b, dj in zip(blocks, d)
            ]
            g = _axis_coords(tile_blocks, rho, shape)
            ok = domain_mask(m, n, g)
        else:
            tile_blocks = [b + dj for b, dj in zip(blocks, d)]
            g = _axis_coords(tile_blocks, rho, shape)
            ok = domain_mask(m, n, g)
            for gj in g:
                ok = ok & (gj >= 0) & (gj < n)
        t = jnp.where(ok, t, 0)
        big = jax.lax.dynamic_update_slice(
            big, t, tuple((dj + 1) * rho for dj in d)
        )
    return big


# ---------------------------------------------------------------------------
# bodies
# ---------------------------------------------------------------------------


class AccumBody(KernelBody):
    """ACCUM: +1 on every simplex element (the memory-bound test)."""

    name = "accum"
    element_local = True

    def tile(self, ctx: BodyContext):
        """One increment of the center tile."""
        return ctx.center + 1


class EDMBody(KernelBody):
    """EDM: sum of pairwise point distances per simplex cell.

    ``out[c] = sum_{a < b} ||p[c_a] - p[c_b]||`` over the cell's
    coordinates — at m=2 exactly the paper's Euclidean distance matrix
    ``||p_i - p_j||`` on the lower triangle; at m=3 the perimeter of
    the triangle ``(p_x, p_y, p_z)`` (arithmetic-heavy at every m).
    Out-of-domain elements are written 0 via the zeros seed.
    """

    name = "edm"
    element_local = True

    def seed(self, p, m: int):
        """Zeros seed: untouched tiles (and masked elements) read 0."""
        n, _ = p.shape
        return jnp.zeros((n,) * m, p.dtype), n

    def extra_arrays(self, p, m: int) -> tuple:
        """One (n, d) point-block operand per cell coordinate."""
        return (p,) * m

    def extra_spec(self, a, p, m, nb, rho, fn):
        """Fetch the ``(rho, d)`` point block of coordinate ``c_a``."""
        d = p.shape[1]

        def _transform(blocks, coords, v, a=a):
            return jnp.clip(coords[a], 0, nb - 1), 0

        return pl.BlockSpec((rho, d), _make_index_map(fn, _transform))

    def tile(self, ctx: BodyContext):
        """Accumulate ``||p_b - p_a||`` over coordinate pairs a < b."""
        m, rho = ctx.m, ctx.rho
        ps = [r[...].astype(jnp.float32) for r in ctx.extras]
        total = jnp.zeros((rho,) * m, jnp.float32)
        for a in range(m):
            for b in range(a + 1, m):
                # (i_b, i_a) orientation: axis m-1-b < axis m-1-a.
                d2 = jnp.sum(
                    (ps[b][:, None, :] - ps[a][None, :, :]) ** 2, axis=-1
                )
                dist = jnp.sqrt(d2)
                shape = [1] * m
                shape[m - 1 - b] = rho
                shape[m - 1 - a] = rho
                total = total + dist.reshape(shape)
        return total


class CABody(KernelBody):
    """CA: one Game-of-Life step (B3/S23 analogue, 3^m - 1 neighbours).

    m=2 wraps periodically on the underlying square (paper §5.1); m >= 3
    uses free boundaries (fixed dead cells outside the simplex).  Cells
    outside the domain are permanently dead; visited out-of-domain
    elements keep their input value (in-place semantics).
    """

    name = "ca"
    halo = True
    element_local = False

    def tile(self, ctx: BodyContext):
        """Decode centre + neighbour count from the halo assembly."""
        m, rho = ctx.m, ctx.rho
        big = ctx.neighborhood
        centre = jax.lax.dynamic_slice(
            big, (rho,) * m, (rho,) * m
        )
        neigh = jnp.zeros((rho,) * m, dtype=big.dtype)
        for d in itertools.product((-1, 0, 1), repeat=m):
            if d == (0,) * m:
                continue
            neigh = neigh + jax.lax.dynamic_slice(
                big, tuple(rho + dj for dj in d), (rho,) * m
            )
        born = (centre == 0) & (neigh == 3)
        survive = (centre == 1) & ((neigh == 2) | (neigh == 3))
        return (born | survive).astype(ctx.dtype)


class MapBody(KernelBody):
    """MAP: materialize the schedule walk itself (the paper's
    theoretical-speedup microbenchmark).

    Output is a ``(steps, m+1)`` int32 table of ``(*coords, valid)``
    per grid step — CHUNK consecutive steps per launch step so the map
    cannot be elided (the CUDA version uses ``volatile`` for this).
    Overrides ``launch``: the output is a table, not a domain array.
    """

    name = "map"
    element_local = True

    def launch(self, kernel: "SimplexKernel", nb: int):
        """Chunked linear walk over the schedule's flattened grid."""
        m, chunk = kernel.m, kernel.chunk
        interpret = resolve_interpret(kernel.interpret)
        if kernel.schedule is not None:
            if kernel.schedule.m != m or kernel.schedule.n != nb:
                raise ValueError(
                    f"explicit schedule is (m={kernel.schedule.m}, "
                    f"nb={kernel.schedule.n}) but the launch needs "
                    f"(m={m}, nb={nb})"
                )
            sched = kernel.schedule
        else:
            sched = _schedule(m, nb, kernel.kind)
        fn, table = sched.map, sched.prefetch
        steps = sched.steps
        grid = sched.grid
        padded = ((steps + chunk - 1) // chunk) * chunk
        width = m + 1

        def _kernel_fn(*refs):
            if table is not None:
                tab_ref, o_ref = refs
                pref = (tab_ref,)
            else:
                (o_ref,) = refs
                pref = ()
            i = pl.program_id(0)
            lin = (
                i * chunk
                + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)[:, 0]
            )
            lin = jnp.minimum(lin, steps - 1)
            ws = []
            rem = lin
            for g in grid:
                ws.append(rem % g)
                rem = rem // g
            out = fn(*ws, *pref)
            coords, valid = out[:-1], out[-1]
            for j, c in enumerate(coords):
                o_ref[:, j] = c.astype(jnp.int32)
            o_ref[:, m] = valid.astype(jnp.int32)

        def _out_map(i, *pref):
            return i, 0

        grid_spec, args = _grid_spec(
            table, (padded // chunk,), [],
            pl.BlockSpec((chunk, width), _out_map),
        )
        out = pallas_launch(
            _kernel_fn,
            interpret=interpret,
            out_shape=jax.ShapeDtypeStruct((padded, width), jnp.int32),
            grid_spec=grid_spec,
        )(*args)
        return out[:steps]

    def xla_executor(self, kernel: "SimplexKernel", nb: int):
        """The walk evaluated as ONE jit program (compiled.py)."""
        from .compiled import schedule_coords_compiled

        return schedule_coords_compiled(
            kernel.m, nb, resolve_kind(kernel.m, nb, kernel.kind)
        )


class _AccumXLA(AccumBody):
    """ACCUM with the fused-XLA executors wired in (the default body)."""

    def xla_executor(self, kernel: "SimplexKernel", x):
        """Route to ``accum2d_compiled`` / ``accum_md_compiled``."""
        from .compiled import accum2d_compiled, accum_md_compiled

        if kernel.m == 2:
            return accum2d_compiled(x, rho=kernel.rho, kind=kernel.kind)
        return accum_md_compiled(x, rho=kernel.rho, kind=kernel.kind)


register_body(_AccumXLA())
register_body(EDMBody())
register_body(CABody())
register_body(MapBody())


# ---------------------------------------------------------------------------
# the launcher
# ---------------------------------------------------------------------------


class SimplexKernel:
    """One launcher for every (body, dimension, schedule kind).

    ``SimplexKernel(body, m)`` resolves the body from the registry and
    launches it over any ``SimplexSchedule`` — the engine handles grid
    shape, scalar prefetch, trash-tile parking, halo assembly,
    execution policy, and composite launch splitting uniformly
    (DESIGN.md §2.3).

    Args:
        body: Registered body name ('map' | 'accum' | 'edm' | 'ca') or
            a ``KernelBody`` instance.
        m: Simplex dimension (m >= 2).
        rho: Tile side (default ``default_rho(m)``).
        kind: Schedule kind, ``'auto'`` for the autotuner.
        interpret: Pallas mode; None resolves per backend (policy.py).
        split: Force the composite per-piece launch split on/off; None
            asks ``repro.autotune.should_split_pieces``.
        chunk: MAP body only — steps materialized per launch step.
        executor: ``'pallas'`` (default) or ``'xla'`` — the fused-XLA
            fallback where the body provides one.
        schedule: An explicit schedule object (``.grid`` / ``.map`` /
            ``.prefetch`` surface, e.g. a ``ShardSchedule`` from
            ``distributed/simplex_sharding.py``) to launch instead of
            resolving ``kind``; must match the operand's (m, nb).
            The launch walks exactly its steps — the per-shard
            execution path of DESIGN.md §7.

    Example:
        >>> import numpy as np
        >>> k = SimplexKernel("accum", m=3, rho=2, kind="table")
        >>> x = np.zeros((4, 4, 4), np.int32)
        >>> int(np.asarray(k(x)).sum())  # V(T(4)) cells incremented
        20
    """

    def __init__(self, body, m: int, *, rho: Optional[int] = None,
                 kind: str = "auto", interpret: Optional[bool] = None,
                 split: Optional[bool] = None, chunk: int = 128,
                 executor: str = "pallas", schedule=None):
        if m < 2:
            raise ValueError(f"m must be >= 2, got {m}")
        if executor not in ("pallas", "xla"):
            raise ValueError(f"unknown executor {executor!r}")
        self.body = get_body(body)
        self.m = m
        self.rho = default_rho(m) if rho is None else rho
        self.kind = kind
        self.interpret = interpret
        self.split = split
        self.chunk = chunk
        self.executor = executor
        self.schedule = schedule

    def __call__(self, x):
        """Launch the body on operand ``x`` (domain array, points, or
        tile count for the MAP body)."""
        if self.executor == "xla":
            out = self.body.xla_executor(self, x)
            if out is None:
                raise NotImplementedError(
                    f"body {self.body.name!r} has no fused-XLA executor; "
                    "use executor='pallas' (interpret mode on CPU)"
                )
            return out
        return self.body.launch(self, x)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"SimplexKernel(body={self.body.name!r}, m={self.m}, "
            f"rho={self.rho}, kind={self.kind!r})"
        )


# ---------------------------------------------------------------------------
# functional entry points (what ops.py and new code call)
# ---------------------------------------------------------------------------


def map_table(nb: int, m: int = 2, kind: str = "hmap", chunk: int = 128,
              interpret: Optional[bool] = None,
              executor: str = "pallas") -> jax.Array:
    """The MAP test at any dimension: ``(steps, m+1)`` int32
    ``(*coords, valid)`` per grid step.

    Args:
        nb: Tile count per side.
        m: Simplex dimension.
        kind: Schedule kind.
        chunk: Steps per launch step.
        interpret: Pallas mode (None = policy).
        executor: 'pallas' or 'xla' (``schedule_coords_compiled``).

    Returns:
        The materialized schedule walk.
    """
    return SimplexKernel(
        "map", m, kind=kind, chunk=chunk, interpret=interpret,
        executor=executor,
    )(nb)


def accum(x: jax.Array, rho: Optional[int] = None, kind: str = "auto",
          interpret: Optional[bool] = None, split: Optional[bool] = None,
          executor: str = "pallas") -> jax.Array:
    """+1 on every simplex element of the m-cube ``x`` (m = x.ndim).

    Args:
        x: ``(n,)*m`` array, ``rho | n``; m=2 uses the inclusive
            lower-triangle domain, m >= 3 the strict simplex.
        rho: Tile side (default per dimension).
        kind: Schedule kind or 'auto'.
        interpret: Pallas mode (None = policy).
        split: Composite per-piece launch split (None = autotuned).
        executor: 'pallas' or 'xla' (fused-XLA executors).

    Returns:
        ``x`` with +1 on the domain; out-of-domain untouched.
    """
    return SimplexKernel(
        "accum", x.ndim, rho=rho, kind=kind, interpret=interpret,
        split=split, executor=executor,
    )(x)


def edm(p: jax.Array, m: int = 2, rho: Optional[int] = None,
        kind: str = "auto", interpret: Optional[bool] = None,
        split: Optional[bool] = None) -> jax.Array:
    """Pairwise-distance field over the m-simplex: the EDM test.

    ``out[c] = sum_{a<b} ||p[c_a] - p[c_b]||`` — the paper's Euclidean
    distance matrix at m=2, its dimension-generic sibling beyond.

    Args:
        p: ``(n, d)`` points.
        m: Simplex dimension of the output field.
        rho: Tile side (default per dimension).
        kind: Schedule kind or 'auto'.
        interpret: Pallas mode (None = policy).
        split: Composite per-piece launch split (None = autotuned).

    Returns:
        ``(n,)*m`` array in ``p.dtype``; 0 outside the domain.
    """
    return SimplexKernel(
        "edm", m, rho=rho, kind=kind, interpret=interpret, split=split,
    )(p)


def ca(state: jax.Array, rho: Optional[int] = None, kind: str = "auto",
       interpret: Optional[bool] = None) -> jax.Array:
    """One Game-of-Life step on the m-simplex (m = state.ndim).

    Args:
        state: ``(n,)*m`` 0/1 array.
        rho: Tile side (default per dimension).
        kind: Schedule kind or 'auto'.
        interpret: Pallas mode (None = policy).

    Returns:
        The stepped state; out-of-domain elements untouched.
    """
    return SimplexKernel(
        "ca", state.ndim, rho=rho, kind=kind, interpret=interpret,
    )(state)


def edm2d(p: jax.Array, rho: Optional[int] = None, kind: str = "auto",
          interpret: Optional[bool] = None) -> jax.Array:
    """The m=2 EDM body — ``out[i, j] = ||p_i - p_j||`` on the
    inclusive lower triangle (engine-built; see ``edm``)."""
    return edm(p, 2, rho=rho, kind=kind, interpret=interpret)


def edm3d(p: jax.Array, rho: Optional[int] = None, kind: str = "auto",
          interpret: Optional[bool] = None,
          split: Optional[bool] = None) -> jax.Array:
    """The m=3 EDM body: per-cell triangle perimeter
    ``||p_x-p_y|| + ||p_x-p_z|| + ||p_y-p_z||`` on T(n) (see ``edm``)."""
    return edm(p, 3, rho=rho, kind=kind, interpret=interpret, split=split)


def edm_md(p: jax.Array, m: int, rho: Optional[int] = None,
           kind: str = "auto", interpret: Optional[bool] = None,
           split: Optional[bool] = None) -> jax.Array:
    """The general-m EDM body (m >= 3; ``edm2d`` serves the triangle).

    Args:
        p: ``(n, d)`` points.
        m: Simplex dimension, m >= 3.
        rho: Tile side (default per dimension).
        kind: Schedule kind or 'auto'.
        interpret: Pallas mode (None = policy).
        split: Composite per-piece launch split (None = autotuned).

    Returns:
        ``(n,)*m`` pairwise-distance field; 0 outside T(n).
    """
    if m < 3:
        raise ValueError("edm_md serves m >= 3; use edm2d for the triangle")
    return edm(p, m, rho=rho, kind=kind, interpret=interpret, split=split)


def ca_md(state: jax.Array, rho: Optional[int] = None, kind: str = "auto",
          interpret: Optional[bool] = None) -> jax.Array:
    """The general-m CA body: (3^m - 1)-neighbour Game of Life on T(n),
    free boundaries (m = state.ndim >= 3; ``ca`` at m=2 wraps).

    Args:
        state: ``(n,)*m`` 0/1 array, m >= 3.
        rho: Tile side (default per dimension).
        kind: Schedule kind or 'auto'.
        interpret: Pallas mode (None = policy).

    Returns:
        The stepped state; out-of-domain elements untouched.
    """
    if state.ndim < 3:
        raise ValueError("ca_md serves m >= 3; use ca for the 2-simplex")
    return ca(state, rho=rho, kind=kind, interpret=interpret)


def accum_md(x: jax.Array, rho: Optional[int] = None, kind: str = "auto",
             interpret: Optional[bool] = None,
             split: Optional[bool] = None) -> jax.Array:
    """The general-m ACCUM body (m = x.ndim >= 3; see ``accum``)."""
    if x.ndim < 3:
        raise ValueError("accum_md serves m >= 3; use accum at m=2")
    return accum(x, rho=rho, kind=kind, interpret=interpret, split=split)


def grid_steps(nb: int, kind: str, m: int = 2) -> int:
    """Grid steps the engine would launch for ``(m, nb, kind)`` after
    kernel-facing kind resolution.

    Args:
        nb: Tile count per side.
        kind: Requested schedule kind.
        m: Simplex dimension.

    Returns:
        Total grid steps of the resolved schedule.
    """
    return _schedule(m, nb, kind).steps
