"""Causal flash attention on the 2-simplex grid — the paper's technique
made a first-class LM feature (DESIGN.md §2, serving hot path §8).

The causal score matrix is a standard 2-simplex: tiles (q_tile, kv_tile)
with kv <= q.  The bounding-box schedule (``kind='bb'``) lowers a full
(nq x nk) grid and discards the upper half with ``pl.when`` — exactly the
paper's BB baseline.  The folded schedule (``kind='folded'``) is the
zero-waste simplex walk: grid (heads, ceil(nq/2) pairs, nq+1 steps),
where pair ``p`` serves query tiles ``p`` and ``nq-1-p``:

    step j <= p:        (q, kv) = (p, j)
    step j >  p:        (q, kv) = (nq-1-p, j-p-1)

Every pair owns exactly ``nq+1`` KV tiles — constant work per grid row
(the paper's parallel-space balance, realized as the RB fold [37], which
the paper shows matches H for 2-simplices), and each query tile's KV
visits are *consecutive*, which the running-softmax recurrence requires.
An odd tile count self-pairs the middle tile (``folded_causal_pairs``'s
odd form): pair ``mid = (nq-1)/2`` has ``nq-1-mid == mid``, so its
second half-walk revisits the same (mid+1)-tile segment — the recurrence
recomputes the identical output and the final flush rewrites it, so the
fold stays branch-free at the cost of one half-row of duplicate work.
Grid steps: nq(nq+1)/2 + nq/2 (even) vs nq^2 for BB — the asymptotic 2x
of the paper's MAP test, with zero per-step predicates off the diagonal.

GQA runs inside the index maps: KV blocks are fetched per *kv head*
(``bh // group``) so grouped query heads share them with no materialized
``jnp.repeat`` — the kernel never touches a (B, Hq, S, D) KV tensor.
Optional additive ``bias`` (broadcastable over batch/head) and
``segment_ids`` (block-diagonal packing mask) ride the same block maps.

The same fold is exposed as ``core.schedule.folded_causal_pairs`` for
sequence-parallel sharding (equal triangle area per shard).

Block sizes default to TPU-native (block_q x head_dim = 128 x 128 MXU
tiles); tests sweep smaller shapes in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .engine import pallas_launch
from .policy import check_tile_alignment, resolve_interpret

NEG_INF = -1e30

__all__ = ["flash_attention", "flash_grid_steps", "flash_fold_pairs"]


def flash_fold_pairs(nq_tiles: int) -> int:
    """Folded-grid pair rows for ``nq_tiles`` query tiles.

    Even counts fold tile ``i`` with ``nq-1-i``; an odd count adds the
    self-paired middle tile as its own row (the ``folded_causal_pairs``
    odd form).

    Args:
        nq_tiles: Query-tile count, >= 1.

    Returns:
        ``ceil(nq_tiles / 2)`` — the folded grid's second dimension.

    Example:
        >>> flash_fold_pairs(4), flash_fold_pairs(5)
        (2, 3)
    """
    if nq_tiles < 1:
        raise ValueError(f"nq_tiles must be >= 1, got {nq_tiles}")
    return (nq_tiles + 1) // 2


def flash_grid_steps(nq_tiles: int, kind: str) -> int:
    """Grid steps the flash kernel launches for ``nq_tiles`` query tiles.

    Args:
        nq_tiles: Query-tile count, >= 1.
        kind: ``'bb'`` (full square) or ``'folded'`` (the simplex fold;
            every pair row walks ``nq+1`` steps — zero waste at even
            counts, one duplicated half-row at odd counts where the
            middle tile self-pairs).

    Returns:
        Total grid steps (excluding the batch*heads axis).

    Raises:
        ValueError: Unknown kind or non-positive tile count — the only
            genuinely unmappable inputs.

    Example:
        >>> flash_grid_steps(4, "bb"), flash_grid_steps(4, "folded")
        (16, 10)
        >>> flash_grid_steps(5, "folded")  # odd: 3 pair rows x 6 steps
        18
    """
    if nq_tiles < 1:
        raise ValueError(f"nq_tiles must be >= 1, got {nq_tiles}")
    if kind == "bb":
        return nq_tiles * nq_tiles
    if kind == "folded":
        return flash_fold_pairs(nq_tiles) * (nq_tiles + 1)
    raise ValueError(f"unknown flash schedule kind {kind!r}")


def _folded_qkv(p, j, nq):
    """Branchless fold: step (p, j) -> (q_tile, kv_tile, is_start, is_last)."""
    second = j > p
    q = jnp.where(second, nq - 1 - p, p)
    kv = jnp.where(second, j - p - 1, j)
    start = (j == 0) | (j == p + 1)
    last = (j == p) | (j == nq)
    return q, kv, start, last


def _bias_index(bias_shape, b, hq):
    """Static (div, mod) mapping from the fused bh axis into a
    broadcast bias leading axis of ``bias_b * bias_h`` slabs."""
    bias_b, bias_h = bias_shape[0], bias_shape[1]
    if bias_b not in (1, b) or bias_h not in (1, hq):
        raise ValueError(
            f"bias must broadcast over (batch={b}, heads={hq}); got "
            f"leading dims {(bias_b, bias_h)}"
        )

    def to_slab(bh):
        batch = bh // hq
        head = bh % hq
        bb = batch % bias_b if bias_b > 1 else 0
        hh = head % bias_h if bias_h > 1 else 0
        return bb * bias_h + hh

    return to_slab


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    bias: jax.Array | None = None,
    segment_ids: jax.Array | None = None,
    kind: str = "folded",
    block_q: int = 128,
    block_kv: int = 128,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Causal self-attention on the simplex grid, GQA-aware.

    This is the batched-prefill/training entry the model layer launches
    (``models.attention.simplex_attention`` — DESIGN.md §8); decode
    keeps the KV-cache strip path.

    Args:
        q: Queries, ``(B, Hq, S, D)``.
        k: Keys, ``(B, Hkv, S, D)`` with ``Hq % Hkv == 0``; grouped
            query heads read each KV block straight from the kv-head
            index map (no materialized repeat).
        v: Values, same shape as ``k``.
        bias: Optional additive logit bias broadcastable to
            ``(B, Hq, S, S)`` — leading dims may each be 1.
        segment_ids: Optional ``(B, S)`` int32 packing ids; attention
            only flows within equal ids (block-diagonal mask).
        kind: ``'folded'`` (simplex fold, ~2x fewer grid steps) or
            ``'bb'`` (bounding-box baseline).
        block_q: Query tile size (clamped to S; must divide S).
        block_kv: KV tile size; the fold pairs tiles 1:1, so it must
            equal ``block_q``.
        scale: Logit scale; defaults to ``1/sqrt(D)``.
        interpret: Pallas mode; ``None`` resolves through
            ``policy.default_interpret()`` (compiled on TPU/GPU,
            interpreter on CPU).

    Returns:
        ``(B, Hq, S, D)`` attention output in ``q.dtype`` (f32 softmax
        accumulation).

    Raises:
        ValueError: Genuinely unmappable shapes — S not divisible by
            the block size, ``block_q != block_kv``, or a bias that
            cannot broadcast.  Odd query-tile counts are mapped via the
            self-pair middle fold, not rejected.
    """
    interpret = resolve_interpret(interpret)
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0 and k.shape == v.shape == (b, hkv, s, d)
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    if s % block_q or s % block_kv:
        raise ValueError(
            f"sequence length {s} must be divisible by the block size "
            f"(block_q={block_q}, block_kv={block_kv})"
        )
    if block_q != block_kv:
        raise ValueError(
            f"fold pairs q/kv tiles 1:1 (square tiles); got "
            f"block_q={block_q} != block_kv={block_kv}"
        )
    nq = s // block_q
    if scale is None:
        scale = 1.0 / (d**0.5)

    if kind == "folded" and nq == 1:
        kind = "bb"  # single tile: nothing to fold
    if kind not in ("folded", "bb"):
        raise ValueError(f"unknown flash schedule kind {kind!r}")
    seg = None if segment_ids is None else segment_ids.astype(jnp.int32)
    return _flash_core(
        kind, block_q, block_kv, float(scale), interpret, q, k, v, bias, seg
    )


def _reference_attention(q, k, v, bias, segment_ids, scale):
    """Plain-XLA causal attention — the kernel's backward-pass oracle.

    Materializes the full (B, Hq, S, S) score matrix (GQA heads via
    ``jnp.repeat``), applies the same NEG_INF causal/segment mask and
    additive bias as the kernel, and lets JAX AD differentiate it.
    Forward outputs stay on the Pallas kernel; only cotangents flow
    through here (DESIGN.md §8).
    """
    b, hq, s, d = q.shape
    g = hq // k.shape[1]
    kf = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    sc = jnp.einsum("bhid,bhjd->bhij", q.astype(jnp.float32) * scale, kf)
    if bias is not None:
        sc = sc + jnp.broadcast_to(bias.astype(jnp.float32), sc.shape)
    mask = jnp.tril(jnp.ones((s, s), bool))[None, None]
    if segment_ids is not None:
        mask = mask & (
            segment_ids[:, None, :, None] == segment_ids[:, None, None, :]
        )
    sc = jnp.where(mask, sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhij,bhjd->bhid", pr, vf)
    return out.astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _flash_core(kind, block_q, block_kv, scale, interpret, q, k, v, bias, seg):
    """Differentiable core: Pallas forward, XLA-reference backward.

    The Pallas interpreter has no JVP rule, so training steps would
    fail at ``jax.grad`` without this wrapper.  The custom VJP keeps
    the simplex-scheduled kernel as the forward (the serving/training
    hot path) and routes cotangents through ``_reference_attention``
    — standard flash-attention practice until a fused backward kernel
    lands (ROADMAP follow-up).
    """
    return _flash_launch(
        kind, block_q, block_kv, scale, interpret, q, k, v, bias, seg
    )


def _flash_core_fwd(kind, block_q, block_kv, scale, interpret, q, k, v,
                    bias, seg):
    out = _flash_launch(
        kind, block_q, block_kv, scale, interpret, q, k, v, bias, seg
    )
    return out, (q, k, v, bias, seg)


def _flash_core_bwd(kind, block_q, block_kv, scale, interpret, res, g):
    q, k, v, bias, seg = res
    if bias is None:
        _, vjp = jax.vjp(
            lambda q_, k_, v_: _reference_attention(q_, k_, v_, None, seg,
                                                    scale),
            q, k, v,
        )
        dq, dk, dv = vjp(g)
        dbias = None
    else:
        _, vjp = jax.vjp(
            lambda q_, k_, v_, b_: _reference_attention(q_, k_, v_, b_, seg,
                                                        scale),
            q, k, v, bias,
        )
        dq, dk, dv, dbias = vjp(g)
    # integer segment ids carry a float0 (symbolic-zero) cotangent
    dseg = None if seg is None else np.zeros(seg.shape, jax.dtypes.float0)
    return dq, dk, dv, dbias, dseg


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def _flash_launch(kind, block_q, block_kv, scale, interpret, q, k, v,
                  bias, segment_ids):
    """Grid/spec construction + the Pallas launch (forward only)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    nq = s // block_q
    g = hq // hkv
    if kind == "folded":
        grid = (b * hq, flash_fold_pairs(nq), nq + 1)

        def q_map(bh, p, j, *_):
            qt, _, _, _ = _folded_qkv(p, j, nq)
            return bh, qt, 0

        def kv_map(bh, p, j, *_):
            _, kt, _, _ = _folded_qkv(p, j, nq)
            return bh // g, kt, 0

        def tile_ids(p, j):
            qt, kt, start, last = _folded_qkv(p, j, nq)
            return qt, kt, start, last, jnp.bool_(True)

    else:
        grid = (b * hq, nq, nq)

        def q_map(bh, qt, kt, *_):
            return bh, qt, 0

        def kv_map(bh, qt, kt, *_):
            return bh // g, kt, 0

        def tile_ids(qt, kt):
            return qt, kt, kt == 0, kt == qt, kt <= qt

    o_map = q_map

    # ---- optional inputs: additive bias and segment-id masking ----------
    extra_in = []
    extra_specs = []
    if bias is not None:
        if bias.ndim != 4:
            raise ValueError(f"bias must be 4-D, got shape {bias.shape}")
        if bias.shape[2:] != (s, s):
            raise ValueError(
                f"bias trailing dims must be ({s}, {s}), got {bias.shape}"
            )
        to_slab = _bias_index(bias.shape, b, hq)
        bias_r = bias.reshape(-1, s, s)

        def bias_map(bh, i, j, *_):
            qt, kt, *_rest = tile_ids(i, j)
            return to_slab(bh), qt, kt

        extra_in.append(bias_r.astype(jnp.float32))
        extra_specs.append(pl.BlockSpec((1, block_q, block_kv), bias_map))
    if segment_ids is not None:
        if segment_ids.shape != (b, s):
            raise ValueError(
                f"segment_ids must be (batch, seq) = ({b}, {s}), got "
                f"{segment_ids.shape}"
            )
        seg = segment_ids.astype(jnp.int32)

        def qseg_map(bh, i, j, *_):
            qt, *_rest = tile_ids(i, j)
            return bh // hq, qt

        def kseg_map(bh, i, j, *_):
            _, kt, *_rest = tile_ids(i, j)
            return bh // hq, kt

        extra_in.extend([seg, seg])
        extra_specs.extend([
            pl.BlockSpec((1, block_q), qseg_map),
            pl.BlockSpec((1, block_kv), kseg_map),
        ])

    has_bias = bias is not None
    has_seg = segment_ids is not None

    def kernel(q_ref, k_ref, v_ref, *refs):
        i = 0
        bias_ref = seg_q_ref = seg_k_ref = None
        if has_bias:
            bias_ref = refs[i]
            i += 1
        if has_seg:
            seg_q_ref, seg_k_ref = refs[i], refs[i + 1]
            i += 2
        o_ref, m_ref, l_ref, acc_ref = refs[i : i + 4]

        qt, kt, start, last, live = tile_ids(
            pl.program_id(1), pl.program_id(2)
        )

        @pl.when(start)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        @pl.when(live)
        def _step():
            qb = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
            kb = k_ref[0].astype(jnp.float32)  # (bk, d)
            sc = jax.lax.dot_general(
                qb, kb, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # (bq, bk)
            if has_bias:
                sc = sc + bias_ref[0]
            on_diag = qt == kt
            rq = qt * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0
            )
            ck = kt * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1
            )
            valid = jnp.logical_not(on_diag & (ck > rq))
            if has_seg:
                valid = valid & (seg_q_ref[0][:, None] == seg_k_ref[0][None, :])
            sc = jnp.where(valid, sc, NEG_INF)
            m_prev = m_ref[:, :1]  # (bq, 1)
            m_cur = jnp.max(sc, axis=1, keepdims=True)
            m_new = jnp.maximum(m_prev, m_cur)
            alpha = jnp.exp(m_prev - m_new)
            pr = jnp.exp(sc - m_new)  # (bq, bk)
            if has_seg:
                # a fully-masked row has m_new == NEG_INF and sc - m_new
                # == 0; zero those probabilities explicitly so packing
                # pads contribute nothing (l stays 0 -> output 0).
                pr = pr * valid.astype(jnp.float32)
            l_new = l_ref[:, :1] * alpha + jnp.sum(pr, axis=1, keepdims=True)
            acc = acc_ref[...] * alpha + jax.lax.dot_general(
                pr,
                v_ref[0].astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
            l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)
            acc_ref[...] = acc

        @pl.when(last)
        def _fin():
            l = l_ref[:, :1]
            l = jnp.where(l == 0.0, 1.0, l)
            o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)

    check_tile_alignment((block_q, d), interpret, what="q block")
    qr = q.reshape(b * hq, s, d)
    kr = k.reshape(b * hkv, s, d)
    vr = v.reshape(b * hkv, s, d)
    out = pallas_launch(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_kv, d), kv_map),
            pl.BlockSpec((1, block_kv, d), kv_map),
            *extra_specs,
        ],
        out_specs=pl.BlockSpec((1, block_q, d), o_map),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),  # running max m
            pltpu.VMEM((block_q, 128), jnp.float32),  # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr, *extra_in)
    return out.reshape(b, hq, s, d)
