"""Causal flash attention on the 2-simplex grid — the paper's technique
made a first-class LM feature (DESIGN.md §2).

The causal score matrix is a standard 2-simplex: tiles (q_tile, kv_tile)
with kv <= q.  The bounding-box schedule (``kind='bb'``) lowers a full
(nq x nk) grid and discards the upper half with ``pl.when`` — exactly the
paper's BB baseline.  The folded schedule (``kind='folded'``) is the
zero-waste simplex walk: grid (heads, nq/2 pairs, nq+1 steps), where pair
``p`` serves query tiles ``p`` and ``nq-1-p``:

    step j <= p:        (q, kv) = (p, j)
    step j >  p:        (q, kv) = (nq-1-p, j-p-1)

Every pair owns exactly ``nq+1`` KV tiles — constant work per grid row
(the paper's parallel-space balance, realized as the RB fold [37], which
the paper shows matches H for 2-simplices), and each query tile's KV
visits are *consecutive*, which the running-softmax recurrence requires.
Grid steps: nq(nq+1)/2 + nq/2  vs  nq^2 for BB — the asymptotic 2x of
the paper's MAP test, with zero per-step predicates off the diagonal.

The same fold is exposed as ``folded_causal_pairs`` for sequence-parallel
sharding (equal triangle area per shard).

Block sizes default to TPU-native (block_q x head_dim = 128 x 128 MXU
tiles); tests sweep smaller shapes in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .engine import pallas_launch
from .policy import check_tile_alignment, resolve_interpret

NEG_INF = -1e30

__all__ = ["flash_attention", "flash_grid_steps"]


def flash_grid_steps(nq_tiles: int, kind: str) -> int:
    """Grid steps the flash kernel launches for ``nq_tiles`` query tiles.

    Args:
        nq_tiles: Query-tile count.
        kind: ``'bb'`` (full square) or ``'folded'`` (zero-waste fold;
            requires an even tile count — the fold pairs tile ``i``
            with ``nq-1-i`` and gives every pair exactly ``nq+1``
            steps, which has no balanced odd-count form).

    Returns:
        Total grid steps (excluding the batch*heads axis).

    Raises:
        ValueError: Unknown kind, or ``'folded'`` with an odd
            ``nq_tiles`` — pad the sequence or use ``'bb'``.
    """
    if kind == "bb":
        return nq_tiles * nq_tiles
    if kind == "folded":
        if nq_tiles % 2:
            raise ValueError(
                f"folded schedule needs an even query-tile count, got "
                f"{nq_tiles}; pad the sequence to an even tile count or "
                "use kind='bb'"
            )
        return (nq_tiles // 2) * (nq_tiles + 1)
    raise ValueError(f"unknown flash schedule kind {kind!r}")


def _folded_qkv(p, j, nq):
    """Branchless fold: step (p, j) -> (q_tile, kv_tile, is_start, is_last)."""
    second = j > p
    q = jnp.where(second, nq - 1 - p, p)
    kv = jnp.where(second, j - p - 1, j)
    start = (j == 0) | (j == p + 1)
    last = (j == p) | (j == nq)
    return q, kv, start, last


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    kind: str = "folded",
    block_q: int = 128,
    block_kv: int = 128,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Causal self-attention, GQA-aware.

    q: (B, Hq, S, D); k, v: (B, Hkv, S, D), Hq % Hkv == 0, S % block == 0.
    Returns (B, Hq, S, D) in q.dtype.  f32 softmax accumulation.
    ``interpret=None`` resolves through ``policy.default_interpret()``
    (compiled on TPU/GPU, interpreter on CPU).
    """
    interpret = resolve_interpret(interpret)
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0 and k.shape == v.shape == (b, hkv, s, d)
    block_q = min(block_q, s)
    block_kv = min(block_kv, s)
    assert s % block_q == 0 and s % block_kv == 0
    assert block_q == block_kv, "fold pairs q/kv tiles 1:1 (square tiles)"
    nq = s // block_q
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (d**0.5)

    if kind == "folded" and nq == 1:
        kind = "bb"  # single tile: nothing to fold
    if kind == "folded":
        if nq % 2:
            raise ValueError(
                f"folded schedule needs an even query-tile count, got "
                f"nq={nq} (seq {s} / block_q {block_q}); pad the "
                "sequence or use kind='bb'"
            )
        grid = (b * hq, nq // 2, nq + 1)

        def q_map(bh, p, j):
            qt, _, _, _ = _folded_qkv(p, j, nq)
            return bh, qt, 0

        def kv_map(bh, p, j):
            _, kt, _, _ = _folded_qkv(p, j, nq)
            return bh // g, kt, 0

        def o_map(bh, p, j):
            qt, _, _, _ = _folded_qkv(p, j, nq)
            return bh, qt, 0

    else:
        grid = (b * hq, nq, nq)

        def q_map(bh, qt, kt):
            return bh, qt, 0

        def kv_map(bh, qt, kt):
            return bh // g, kt, 0

        def o_map(bh, qt, kt):
            return bh, qt, 0

    def kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        if kind == "folded":
            p, j = pl.program_id(1), pl.program_id(2)
            qt, kt, start, last = _folded_qkv(p, j, nq)
            live = jnp.bool_(True)
        else:
            qt, kt = pl.program_id(1), pl.program_id(2)
            start = kt == 0
            last = kt == qt  # causal: last useful kv tile is the diagonal
            live = kt <= qt

        @pl.when(start)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        @pl.when(live)
        def _step():
            qb = q_ref[0].astype(jnp.float32) * scale  # (bq, d)
            kb = k_ref[0].astype(jnp.float32)  # (bk, d)
            sc = jax.lax.dot_general(
                qb, kb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
            )  # (bq, bk)
            on_diag = qt == kt
            rq = qt * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0
            )
            ck = kt * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1
            )
            sc = jnp.where(on_diag & (ck > rq), NEG_INF, sc)
            m_prev = m_ref[:, :1]  # (bq, 1)
            m_cur = jnp.max(sc, axis=1, keepdims=True)
            m_new = jnp.maximum(m_prev, m_cur)
            alpha = jnp.exp(m_prev - m_new)
            pr = jnp.exp(sc - m_new)  # (bq, bk)
            l_new = l_ref[:, :1] * alpha + jnp.sum(pr, axis=1, keepdims=True)
            acc = acc_ref[...] * alpha + jax.lax.dot_general(
                pr,
                v_ref[0].astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
            l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)
            acc_ref[...] = acc

        @pl.when(last)
        def _fin():
            l = l_ref[:, :1]
            l = jnp.where(l == 0.0, 1.0, l)
            o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)

    check_tile_alignment((block_q, d), interpret, what="q block")
    qr = q.reshape(b * hq, s, d)
    kr = k.reshape(b * hkv, s, d)
    vr = v.reshape(b * hkv, s, d)
    out = pallas_launch(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b * hq, s, d), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_kv, d), kv_map),
            pl.BlockSpec((1, block_kv, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), o_map),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),  # running max m
            pltpu.VMEM((block_q, 128), jnp.float32),  # running denom l
            pltpu.VMEM((block_q, d), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, s, d)
