"""Frozen hand-rolled Pallas kernels — the differential baseline.

These are the original per-(body, dimension) kernels that predate the
dimension-generic ``SimplexKernel`` engine (``kernels/engine.py``,
DESIGN.md §2.3): one hand-written ``pallas_call`` per workload, with the
hand-rolled ``make_map(dy, dx)`` / ``make_map(dz, dy, dx)`` halo
closures the engine's 3^m subsystem replaced.

They are kept **verbatim** (modulo routing the launch through
``engine.pallas_launch``, the policy front door) so the differential
parity harness (``tests/test_engine_parity.py``) can compare the engine
against a truly independent implementation — if the deprecated
``simplex_kernels`` wrappers simply delegated to the engine, the parity
suite would be comparing the engine with itself.

Do not add new kernels here and do not "fix" these to share code with
the engine: their value is precisely that they share nothing with it
beyond the schedule subsystem and the launch policy.  New workloads are
body registrations in ``kernels/engine.py``.

All kernels draw their grid walk from the unified
``core.schedule.SimplexSchedule`` subsystem (DESIGN.md §2.2); the
``kind`` argument selects the registered schedule for the kernel's
dimension (``hmap`` / ``rb`` / ``bb`` for the 2-simplex's (w, h) grid;
``hmap`` / ``octant`` / ``bb`` / ``table`` / ``composite`` for the
linear-grid m >= 3 kernels).  Execution mode is resolved per backend by
``kernels/policy.py``; out-of-domain grid steps write to a dedicated
trash tile appended to the output so no live data is clobbered by
Pallas' end-of-step block flush.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.schedule import SimplexSchedule, resolve_kind

from .engine import pallas_launch
from .policy import check_tile_alignment, resolve_interpret

__all__ = [
    "map2d",
    "accum2d",
    "edm2d",
    "ca2d",
    "accum3d",
    "ca3d",
    "accum_md",
    "grid_steps_2d",
    "grid_steps_3d",
]


# ---------------------------------------------------------------------------
# schedule plumbing
# ---------------------------------------------------------------------------


def _schedule(m: int, nb: int, kind: str) -> SimplexSchedule:
    """Resolve the schedule, enforcing the legacy 2D kind restriction."""
    if m == 2 and kind in ("table", "composite"):
        raise ValueError(
            f"the 2D kernels launch a (w, h) grid; kind={kind!r} (linear "
            "walk) is only wired for the m >= 3 kernels — use kind='hmap', "
            "'rb', or 'bb'"
        )
    return SimplexSchedule(m, nb, resolve_kind(m, nb, kind))


def grid_steps_2d(nb: int, kind: str) -> int:
    """Grid steps of the legacy 2D (w, h)-grid schedule."""
    return _schedule(2, nb, kind).steps


# ---------------------------------------------------------------------------
# MAP — mapping stage only (paper's theoretical-speedup microbenchmark).
# ---------------------------------------------------------------------------


def map2d(
    nb: int, kind: str = "hmap", chunk: int = 128, interpret: bool | None = None
) -> jax.Array:
    """Returns (steps, 3) int32: (x, y, valid) per grid step."""
    interpret = resolve_interpret(interpret)
    sched = _schedule(2, nb, kind)
    (w, h), fn = sched.grid, sched.map
    steps = sched.steps
    padded = ((steps + chunk - 1) // chunk) * chunk

    def kernel(o_ref):
        i = pl.program_id(0)
        lin = i * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)[:, 0]
        lin = jnp.minimum(lin, steps - 1)
        wy = lin // w
        wx = lin - wy * w
        x, y, v = fn(wx, wy)
        o_ref[:, 0] = x.astype(jnp.int32)
        o_ref[:, 1] = y.astype(jnp.int32)
        o_ref[:, 2] = v.astype(jnp.int32)

    out = pallas_launch(
        kernel,
        out_shape=jax.ShapeDtypeStruct((padded, 3), jnp.int32),
        grid=(padded // chunk,),
        out_specs=pl.BlockSpec((chunk, 3), lambda i: (i, 0)),
        interpret=interpret,
    )()
    return out[:steps]


# ---------------------------------------------------------------------------
# ACCUM — +1 on each simplex element (memory-bound test)
# ---------------------------------------------------------------------------


def accum2d(
    x: jax.Array,
    rho: int = 8,
    kind: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """+1 on the inclusive lower triangle of x (n x n, rho | n).

    Untouched (out-of-domain) tiles keep their input value via
    input/output aliasing — in-place semantics like the CUDA original.
    """
    n = x.shape[0]
    assert x.shape == (n, n) and n % rho == 0
    interpret = resolve_interpret(interpret)
    check_tile_alignment((rho, rho), interpret)
    nb = n // rho
    sched = _schedule(2, nb, kind)
    (w, h), fn = sched.grid, sched.map

    def in_map(wx, wy):
        xx, yy, v = fn(wx, wy)
        return yy, xx  # (row-block, col-block)

    def kernel(x_ref, o_ref):
        wx, wy = pl.program_id(0), pl.program_id(1)
        xb, yb, valid = fn(wx, wy)
        row0 = yb * rho
        col0 = xb * rho
        r = row0 + jax.lax.broadcasted_iota(jnp.int32, (rho, rho), 0)
        c = col0 + jax.lax.broadcasted_iota(jnp.int32, (rho, rho), 1)
        tri = (c <= r) & valid
        o_ref[...] = jnp.where(tri, x_ref[...] + 1, x_ref[...])

    return pallas_launch(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(w, h),
        in_specs=[pl.BlockSpec((rho, rho), in_map)],
        out_specs=pl.BlockSpec((rho, rho), in_map),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(x)


# ---------------------------------------------------------------------------
# EDM — Euclidean distance matrix (arithmetic-heavy test)
# ---------------------------------------------------------------------------


def edm2d(
    p: jax.Array,
    rho: int = 8,
    kind: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """out[i, j] = ||p_i - p_j|| on the inclusive lower triangle.

    p: (n, d).  Out-of-domain tiles are written 0 via a zeros-aliased
    output (H/RB schedules never visit them; BB writes zeros there).
    """
    n, d = p.shape
    assert n % rho == 0
    interpret = resolve_interpret(interpret)
    check_tile_alignment((rho, rho), interpret)
    nb = n // rho
    sched = _schedule(2, nb, kind)
    (w, h), fn = sched.grid, sched.map

    def rows_map(wx, wy):
        _, yy, _ = fn(wx, wy)
        return yy, 0

    def cols_map(wx, wy):
        xx, _, _ = fn(wx, wy)
        return xx, 0

    def out_map(wx, wy):
        xx, yy, _ = fn(wx, wy)
        return yy, xx

    def kernel(pr_ref, pc_ref, z_ref, o_ref):
        del z_ref  # zeros input present only for output aliasing
        wx, wy = pl.program_id(0), pl.program_id(1)
        xb, yb, valid = fn(wx, wy)
        pr = pr_ref[...].astype(jnp.float32)  # (rho, d) query rows
        pc = pc_ref[...].astype(jnp.float32)  # (rho, d) cols
        d2 = jnp.sum((pr[:, None, :] - pc[None, :, :]) ** 2, axis=-1)
        dist = jnp.sqrt(d2)
        r = yb * rho + jax.lax.broadcasted_iota(jnp.int32, (rho, rho), 0)
        c = xb * rho + jax.lax.broadcasted_iota(jnp.int32, (rho, rho), 1)
        tri = (c <= r) & valid
        o_ref[...] = jnp.where(tri, dist, 0.0).astype(o_ref.dtype)

    zeros = jnp.zeros((n, n), dtype=p.dtype)
    return pallas_launch(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), p.dtype),
        grid=(w, h),
        in_specs=[
            pl.BlockSpec((rho, d), rows_map),
            pl.BlockSpec((rho, d), cols_map),
            pl.BlockSpec((rho, rho), out_map),
        ],
        out_specs=pl.BlockSpec((rho, rho), out_map),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(p, p, zeros)


# ---------------------------------------------------------------------------
# CA2D — game of life on the triangle, periodic wrap (memory-bound, halos)
# ---------------------------------------------------------------------------


def ca2d(
    state: jax.Array,
    rho: int = 8,
    kind: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """One GoL step on the inclusive lower triangle (periodic underlying
    square).  Nine shifted input refs provide the halo — the standard
    Pallas stencil pattern (no element-offset reads on TPU)."""
    n = state.shape[0]
    assert state.shape == (n, n) and n % rho == 0
    interpret = resolve_interpret(interpret)
    check_tile_alignment((rho, rho), interpret)
    nb = n // rho
    sched = _schedule(2, nb, kind)
    (w, h), fn = sched.grid, sched.map

    shifts = [(dy, dx) for dy in (-1, 0, 1) for dx in (-1, 0, 1)]

    def make_map(dy, dx):
        def m(wx, wy):
            xx, yy, _ = fn(wx, wy)
            return (yy + dy) % nb, (xx + dx) % nb

        return m

    def out_map(wx, wy):
        xx, yy, _ = fn(wx, wy)
        return yy, xx

    def kernel(*refs):
        in_refs = refs[:9]
        o_ref = refs[9]
        wx, wy = pl.program_id(0), pl.program_id(1)
        xb, yb, valid = fn(wx, wy)

        def tri_of(tile_yb, tile_xb, arr):
            r = tile_yb * rho + jax.lax.broadcasted_iota(jnp.int32, (rho, rho), 0)
            c = tile_xb * rho + jax.lax.broadcasted_iota(jnp.int32, (rho, rho), 1)
            return jnp.where(c <= r, arr, 0)

        # assemble (3*rho, 3*rho) neighbourhood, each tile masked by the
        # triangle predicate of ITS OWN (wrapped) position — matching the
        # jnp.roll-of-masked-state reference semantics.
        rowsl = []
        for dy in (-1, 0, 1):
            row = []
            for dx in (-1, 0, 1):
                i = shifts.index((dy, dx))
                t = in_refs[i][...]
                row.append(tri_of((yb + dy) % nb, (xb + dx) % nb, t))
            rowsl.append(jnp.concatenate(row, axis=1))
        big = jnp.concatenate(rowsl, axis=0)  # (3rho, 3rho)
        centre = big[rho : 2 * rho, rho : 2 * rho]
        neigh = jnp.zeros((rho, rho), dtype=big.dtype)
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                if dx == 0 and dy == 0:
                    continue
                neigh = neigh + big[
                    rho + dy : 2 * rho + dy, rho + dx : 2 * rho + dx
                ]
        born = (centre == 0) & (neigh == 3)
        survive = (centre == 1) & ((neigh == 2) | (neigh == 3))
        new = (born | survive).astype(o_ref.dtype)
        r = yb * rho + jax.lax.broadcasted_iota(jnp.int32, (rho, rho), 0)
        c = xb * rho + jax.lax.broadcasted_iota(jnp.int32, (rho, rho), 1)
        tri = (c <= r) & valid
        o_ref[...] = jnp.where(tri, new, in_refs[4][...])

    return pallas_launch(
        kernel,
        out_shape=jax.ShapeDtypeStruct(state.shape, state.dtype),
        grid=(w, h),
        in_specs=[pl.BlockSpec((rho, rho), make_map(dy, dx)) for dy, dx in shifts],
        out_specs=pl.BlockSpec((rho, rho), out_map),
        input_output_aliases={4: 0},  # centre ref aliases the output
        interpret=interpret,
    )(*([state] * 9))


# ---------------------------------------------------------------------------
# 3-simplex schedules
# ---------------------------------------------------------------------------


def _sched_linear(m: int, nb: int, kind: str):
    """Returns (steps, map_fn, table) from the SimplexSchedule subsystem —
    map_fn: (lin[, tab_ref]) -> (*coords, valid).

    ``table`` is the schedule's scalar-prefetch payload when the walk is
    table-driven (the TPU-idiomatic exact form: the index map reads m
    int32s from SMEM per grid step), else None and the map is pure index
    arithmetic.
    """
    sched = _schedule(m, nb, kind)
    return sched.steps, sched.map, sched.prefetch


def _launch_plan(m: int, nb: int, kind: str, split: bool | None = None):
    """[(steps, map_fn, table)] — one entry per ``pallas_call`` launch.

    Composite schedules pay O(pieces) selects per grid step inside the
    branchless map; when that chain dominates (many pieces, enough
    steps to amortize per-launch overhead — see
    ``repro.autotune.should_split_pieces``) the schedule is split into
    one launch per piece, each decoding only its own factor chain.
    Splitting is only used by the element-local accumulate kernels:
    pieces cover disjoint tiles, so chaining launches through the
    aliased output is exact.  ``split`` forces the decision either way.
    """
    sched = _schedule(m, nb, kind)
    if sched.kind == "composite":
        subs = sched.split_pieces()
        if split is None:
            from repro.autotune import should_split_pieces

            split = should_split_pieces(len(subs), sched.steps)
        if split and len(subs) > 1:
            return [(s.steps, s.map, None) for s in subs]
    return [(sched.steps, sched.map, sched.prefetch)]


def grid_steps_3d(nb: int, kind: str) -> int:
    """Grid steps of the legacy 3D linear-grid schedule."""
    return _schedule(3, nb, kind).steps


def accum3d(
    x: jax.Array,
    rho: int = 4,
    kind: str = "auto",
    interpret: bool | None = None,
    split: bool | None = None,
) -> jax.Array:
    """+1 on T(n) = {x+y+z < n}; axes (z, y, x); rho | n."""
    n = x.shape[0]
    assert x.shape == (n, n, n) and n % rho == 0
    interpret = resolve_interpret(interpret)
    check_tile_alignment((rho, rho, rho), interpret)
    nb = n // rho

    xp = jnp.concatenate([x, jnp.zeros((rho, n, n), x.dtype)], axis=0)
    for steps, fn, table in _launch_plan(3, nb, kind, split):

        def in_map(i, *pref, fn=fn):
            bx, by, bz, v = fn(i, *pref)
            # invalid steps park on the trash tile (last z block of padding)
            bz = jnp.where(v, bz, nb)
            return bz, by, bx

        def kernel(*refs, fn=fn, table=table):
            if table is not None:
                tab_ref, x_ref, o_ref = refs
                pref = (tab_ref,)
            else:
                x_ref, o_ref = refs
                pref = ()
            i = pl.program_id(0)
            bx, by, bz, valid = fn(i, *pref)
            gz = bz * rho + jax.lax.broadcasted_iota(
                jnp.int32, (rho, rho, rho), 0
            )
            gy = by * rho + jax.lax.broadcasted_iota(
                jnp.int32, (rho, rho, rho), 1
            )
            gx = bx * rho + jax.lax.broadcasted_iota(
                jnp.int32, (rho, rho, rho), 2
            )
            tet_m = ((gx + gy + gz) < n) & valid
            o_ref[...] = jnp.where(tet_m, x_ref[...] + 1, x_ref[...])

        grid_spec, args = _grid_spec(
            table, steps, [pl.BlockSpec((rho, rho, rho), in_map)],
            pl.BlockSpec((rho, rho, rho), in_map),
        )
        xp = pallas_launch(
            kernel,
            out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
            grid_spec=grid_spec,
            input_output_aliases={len(args): 0},
            interpret=interpret,
        )(*args, xp)
    return xp[:n]


def _grid_spec(table, steps, in_specs, out_specs):
    """Plain grid or scalar-prefetch grid, matching the schedule kind."""
    if table is None:
        return (
            pl.GridSpec(grid=(steps,), in_specs=in_specs, out_specs=out_specs),
            (),
        )
    from jax.experimental.pallas import tpu as pltpu

    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(steps,),
        in_specs=in_specs,
        out_specs=out_specs,
    )
    return spec, (jnp.asarray(table),)


def ca3d(
    state: jax.Array,
    rho: int = 4,
    kind: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """One 26-neighbour GoL step on T(n), free boundaries.

    27 shifted input refs (clamped at the domain edge; the true-coordinate
    mask zeroes out-of-range contributions, so clamp duplicates are inert).
    Always a single launch — the halo reads make per-piece chaining
    unsound (a split piece would read neighbours already stepped).
    """
    n = state.shape[0]
    assert state.shape == (n, n, n) and n % rho == 0
    interpret = resolve_interpret(interpret)
    check_tile_alignment((rho, rho, rho), interpret)
    nb = n // rho
    steps, fn, table = _sched_linear(3, nb, kind)
    shifts = [
        (dz, dy, dx) for dz in (-1, 0, 1) for dy in (-1, 0, 1) for dx in (-1, 0, 1)
    ]

    def make_map(dz, dy, dx):
        def m(i, *pref):
            bx, by, bz, v = fn(i, *pref)
            bz2 = jnp.clip(bz + dz, 0, nb - 1)
            by2 = jnp.clip(by + dy, 0, nb - 1)
            bx2 = jnp.clip(bx + dx, 0, nb - 1)
            return jnp.where(v, bz2, nb), by2, bx2

        return m

    def out_map(i, *pref):
        bx, by, bz, v = fn(i, *pref)
        return jnp.where(v, bz, nb), by, bx

    centre_idx = shifts.index((0, 0, 0))

    def kernel(*refs):
        if table is not None:
            pref = (refs[0],)
            refs = refs[1:]
        else:
            pref = ()
        in_refs = refs[:27]
        o_ref = refs[27]
        i = pl.program_id(0)
        bx, by, bz, valid = fn(i, *pref)

        big = jnp.zeros((3 * rho, 3 * rho, 3 * rho), dtype=state.dtype)
        for si, (dz, dy, dx) in enumerate(shifts):
            t = in_refs[si][...]
            # mask by the TRUE coordinates of this halo tile
            gz = (bz + dz) * rho + jax.lax.broadcasted_iota(
                jnp.int32, (rho, rho, rho), 0
            )
            gy = (by + dy) * rho + jax.lax.broadcasted_iota(
                jnp.int32, (rho, rho, rho), 1
            )
            gx = (bx + dx) * rho + jax.lax.broadcasted_iota(
                jnp.int32, (rho, rho, rho), 2
            )
            ok = (
                (gz >= 0) & (gz < n) & (gy >= 0) & (gy < n) & (gx >= 0) & (gx < n)
                & ((gx + gy + gz) < n)
            )
            t = jnp.where(ok, t, 0)
            big = jax.lax.dynamic_update_slice(
                big, t, ((dz + 1) * rho, (dy + 1) * rho, (dx + 1) * rho)
            )
        centre = big[rho : 2 * rho, rho : 2 * rho, rho : 2 * rho]
        neigh = jnp.zeros((rho, rho, rho), dtype=big.dtype)
        for dz, dy, dx in shifts:
            if dz == dy == dx == 0:
                continue
            neigh = neigh + jax.lax.dynamic_slice(
                big, (rho + dz, rho + dy, rho + dx), (rho, rho, rho)
            )
        born = (centre == 0) & (neigh == 3)
        survive = (centre == 1) & ((neigh == 2) | (neigh == 3))
        new = (born | survive).astype(o_ref.dtype)
        gz = bz * rho + jax.lax.broadcasted_iota(jnp.int32, (rho, rho, rho), 0)
        gy = by * rho + jax.lax.broadcasted_iota(jnp.int32, (rho, rho, rho), 1)
        gx = bx * rho + jax.lax.broadcasted_iota(jnp.int32, (rho, rho, rho), 2)
        tet_m = ((gx + gy + gz) < n) & valid
        o_ref[...] = jnp.where(tet_m, new, in_refs[centre_idx][...])

    sp = jnp.concatenate([state, jnp.zeros((rho, n, n), state.dtype)], axis=0)
    grid_spec, args = _grid_spec(
        table,
        steps,
        [pl.BlockSpec((rho, rho, rho), make_map(*s)) for s in shifts],
        pl.BlockSpec((rho, rho, rho), out_map),
    )
    out = pallas_launch(
        kernel,
        out_shape=jax.ShapeDtypeStruct(sp.shape, state.dtype),
        grid_spec=grid_spec,
        input_output_aliases={len(args) + centre_idx: 0},
        interpret=interpret,
    )(*args, *([sp] * 27))
    return out[:n]


# ---------------------------------------------------------------------------
# ACCUM_MD — +1 on each cell of the general m-simplex.
# ---------------------------------------------------------------------------


def accum_md(
    x: jax.Array,
    rho: int = 2,
    kind: str = "auto",
    interpret: bool | None = None,
    split: bool | None = None,
) -> jax.Array:
    """+1 on T(n) = {sum(coords) < n} for an m-cube input of shape (n,)*m.

    m is taken from ``x.ndim`` (any m >= 3 — the linear-grid walks; the
    2-simplex has dedicated kernels above).  The walk comes from
    ``SimplexSchedule(m, n/rho, kind)``; schedule coordinates are in math
    order (x_0 fastest) and array axis j holds x_{m-1-j}, matching the
    3D kernels' (z, y, x) layout.  Out-of-domain grid steps park on a
    trash tile appended along axis 0; untouched tiles keep their input
    value via aliasing (in-place semantics).  Composite schedules may be
    split into one launch per piece (``split``; see ``_launch_plan``).
    """
    m = x.ndim
    assert m >= 3, "use accum2d for the 2-simplex (its grid is (w, h))"
    n = x.shape[0]
    assert all(s == n for s in x.shape) and n % rho == 0
    interpret = resolve_interpret(interpret)
    check_tile_alignment((rho,) * m, interpret)
    nb = n // rho

    xp = jnp.concatenate(
        [x, jnp.zeros((rho,) + x.shape[1:], x.dtype)], axis=0
    )
    for steps, fn, table in _launch_plan(m, nb, kind, split):

        def blocks_of(i, pref, fn=fn):
            out = fn(i, *pref)
            coords, v = out[:-1], out[-1]
            return tuple(coords[::-1]), v  # axis order: axis 0 = x_{m-1}

        def in_map(i, *pref, blocks_of=blocks_of):
            blocks, v = blocks_of(i, pref)
            return (jnp.where(v, blocks[0], nb),) + blocks[1:]

        def kernel(*refs, blocks_of=blocks_of, table=table):
            if table is not None:
                pref = (refs[0],)
                refs = refs[1:]
            else:
                pref = ()
            x_ref, o_ref = refs
            i = pl.program_id(0)
            blocks, valid = blocks_of(i, pref)
            shape = (rho,) * m
            gsum = jnp.zeros(shape, jnp.int32)
            for ax in range(m):
                gsum = gsum + blocks[ax] * rho + jax.lax.broadcasted_iota(
                    jnp.int32, shape, ax
                )
            mask = (gsum < n) & valid
            o_ref[...] = jnp.where(mask, x_ref[...] + 1, x_ref[...])

        spec = pl.BlockSpec((rho,) * m, in_map)
        grid_spec, args = _grid_spec(table, steps, [spec], spec)
        xp = pallas_launch(
            kernel,
            out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
            grid_spec=grid_spec,
            input_output_aliases={len(args): 0},
            interpret=interpret,
        )(*args, xp)
    return xp[:n]
