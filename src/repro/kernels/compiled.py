"""Fused-XLA compiled execution of simplex schedules (DESIGN.md §5).

Interpret-mode Pallas runs a Python loop per grid step — the numbers it
produces measure the emulator, not the hardware.  On TPU/GPU the fix is
``interpret=False`` (the schedule map compiles as a real
``BlockSpec.index_map``); on hosts whose Pallas backend can only
interpret (CPU: "Only interpret mode is supported on CPU backend"), the
compiled counterpart lives here: the *entire* schedule walk — the same
branchless index arithmetic the index_map uses — is traced into ONE
``jax.jit`` program (vectorized over every grid step) and executed as a
fused gather/mask/scatter.  Same schedule, same arithmetic, zero
per-step host round-trips.

Two surfaces:

* ``schedule_coords_compiled(m, n, kind)`` — the compiled index_map
  itself, evaluated for every grid step in one XLA program; bit-equal
  to ``SimplexSchedule.table()`` (the host-built step list).  This is
  the compiled/interpret parity object tests assert on.
* ``accum2d_compiled`` / ``accum3d_compiled`` / ``accum_md_compiled`` —
  compiled executors for the ACCUM tests, numerically identical to the
  interpret-mode kernels in ``simplex_kernels.py``.  Jitted programs
  are cached per (shape, dtype, rho, kind).

Scatter note: every registered schedule visits each data tile at most
once over its *valid* steps, and invalid steps contribute a zero update,
so the scatter-add form is exact (no double updates).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import SimplexSchedule, resolve_kind

__all__ = [
    "schedule_coords_compiled",
    "accum2d_compiled",
    "accum3d_compiled",
    "accum_md_compiled",
]


def _grid_unflatten(sched: SimplexSchedule, lin):
    """lin -> one index array per grid axis (axis 0 fastest), as .table()."""
    ws = []
    for g in sched.grid:
        ws.append(lin % g)
        lin = lin // g
    return ws


def schedule_coords_compiled(m: int, n: int, kind: str) -> np.ndarray:
    """Evaluate a schedule's map for every grid step in ONE jit program.

    The map runs exactly as a compiled ``BlockSpec.index_map`` would —
    traced jnp arithmetic, no host interpreter — vectorized over
    ``arange(steps)``.  Table-driven kinds receive their prefetch
    payload as a device array, mirroring the SMEM scalar-prefetch read.

    Args:
        m: Simplex dimension.
        n: Side length in tile units.
        kind: Exact registered kind (no ``'auto'``; construct what you
            assert on).

    Returns:
        ``(steps, m+1)`` int32 array ``(*coords, valid)`` — comparable
        bit-for-bit with ``SimplexSchedule.table()``.
    """
    sched = SimplexSchedule(m, n, kind)
    steps = sched.steps
    table = sched.prefetch

    @jax.jit
    def run(tab):
        lin = jnp.arange(steps, dtype=jnp.int32)
        ws = _grid_unflatten(sched, lin)
        args = tuple(ws) + ((tab,) if tab is not None else ())
        out = sched.map(*args)
        coords, valid = out[:-1], out[-1]
        cols = [jnp.asarray(c).astype(jnp.int32) for c in coords]
        cols.append(jnp.asarray(valid).astype(jnp.int32))
        return jnp.stack(cols, axis=1)

    return np.asarray(run(None if table is None else jnp.asarray(table)))


def _resolve_2d_kind(nb: int, kind: str) -> str:
    kind = resolve_kind(2, nb, kind)
    if kind in ("table", "composite"):
        raise ValueError(
            f"accum2d_compiled uses the (w, h)-grid kinds; got {kind!r}"
        )
    return kind


@functools.lru_cache(maxsize=64)
def _accum2d_program(n: int, rho: int, kind: str, dtype_name: str):
    nb = n // rho
    sched = SimplexSchedule(2, nb, _resolve_2d_kind(nb, kind))
    steps = sched.steps

    @jax.jit
    def run(x):
        lin = jnp.arange(steps, dtype=jnp.int32)
        ws = _grid_unflatten(sched, lin)
        xb, yb, valid = sched.map(*ws)
        # (steps, rho, rho) element coordinates of each visited tile
        rr = jax.lax.broadcasted_iota(jnp.int32, (steps, rho, rho), 1)
        cc = jax.lax.broadcasted_iota(jnp.int32, (steps, rho, rho), 2)
        rows = yb.astype(jnp.int32)[:, None, None] * rho + rr
        cols = xb.astype(jnp.int32)[:, None, None] * rho + cc
        tri = (cols <= rows) & valid[:, None, None]
        upd = tri.astype(x.dtype)
        return x.at[rows, cols].add(upd, mode="drop")

    return run


def accum2d_compiled(x: jax.Array, rho: int = 8, kind: str = "auto"):
    """Compiled ACCUM on the 2-simplex: one fused XLA program.

    Numerically identical to ``simplex_kernels.accum2d`` (untouched
    tiles keep their input value).  ``kind='auto'`` resolves through the
    autotuner, like the Pallas kernels.

    Args:
        x: (n, n) array, ``rho | n``.
        rho: Square tile side.
        kind: Schedule kind (``hmap``/``rb``/``bb``/``auto``).

    Returns:
        x with +1 on the inclusive lower triangle.
    """
    n = x.shape[0]
    assert x.shape == (n, n) and n % rho == 0
    return _accum2d_program(n, rho, kind, jnp.asarray(x).dtype.name)(x)


@functools.lru_cache(maxsize=64)
def _accum_md_program(m: int, n: int, rho: int, kind: str, dtype_name: str):
    nb = n // rho
    sched = SimplexSchedule(m, nb, resolve_kind(m, nb, kind))
    steps = sched.steps
    table = sched.prefetch
    tile = (rho,) * m

    @jax.jit
    def run(x, tab):
        lin = jnp.arange(steps, dtype=jnp.int32)
        args = (lin,) + ((tab,) if tab is not None else ())
        out = sched.map(*args)
        coords, valid = out[:-1], out[-1]
        blocks = tuple(coords[::-1])  # array axis j holds x_{m-1-j}
        shape = (steps,) + tile
        idx = []
        gsum = jnp.zeros(shape, jnp.int32)
        for ax in range(m):
            g = blocks[ax].astype(jnp.int32).reshape(
                (steps,) + (1,) * m
            ) * rho + jax.lax.broadcasted_iota(jnp.int32, shape, ax + 1)
            idx.append(g)
            gsum = gsum + g
        mask = (gsum < n) & valid.reshape((steps,) + (1,) * m)
        upd = mask.astype(x.dtype)
        return x.at[tuple(idx)].add(upd, mode="drop")

    return run, None if table is None else jnp.asarray(table)


def accum_md_compiled(x: jax.Array, rho: int = 2, kind: str = "auto"):
    """Compiled general-m ACCUM (m = x.ndim >= 3): one fused XLA program.

    The schedule's linear walk — including the composite piece decode or
    the recursion's level decode — is traced once over all grid steps
    and lowered by XLA; table kinds read their payload from a device
    array.  Matches ``simplex_kernels.accum_md`` exactly.

    Args:
        x: (n,)*m array, ``rho | n``.
        rho: Cubic tile side.
        kind: Schedule kind or ``'auto'``.

    Returns:
        x with +1 on T(n) = {sum(coords) < n}.
    """
    m = x.ndim
    assert m >= 3, "use accum2d_compiled for the 2-simplex"
    n = x.shape[0]
    assert all(s == n for s in x.shape) and n % rho == 0
    run, table = _accum_md_program(m, n, rho, kind, jnp.asarray(x).dtype.name)
    return run(x, table)


def accum3d_compiled(x: jax.Array, rho: int = 4, kind: str = "auto"):
    """Compiled ACCUM3D — the m=3 instance of ``accum_md_compiled``.

    Args:
        x: (n, n, n) array with axes (z, y, x), ``rho | n``.
        rho: Cubic tile side.
        kind: Schedule kind or ``'auto'``.

    Returns:
        x with +1 on T(n) = {x+y+z < n}.
    """
    assert x.ndim == 3
    return accum_md_compiled(x, rho=rho, kind=kind)


def compiled_grid_shape(m: int, n: int, kind: str) -> Tuple[int, ...]:
    """Grid of the schedule a compiled executor would launch (inspection)."""
    return SimplexSchedule(m, n, resolve_kind(m, n, kind)).grid
