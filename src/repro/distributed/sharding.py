"""Partition rules: parameters, optimizer state, batches, caches.

Mesh axes: ('pod', 'data', 'model') multi-pod / ('data', 'model')
single-pod.  'pod'+'data' form the FSDP/DP axes (``dp``); 'model' is the
tensor/expert-parallel axis.

Parameters follow Megatron-style col/row rules with ZeRO-3 storage: the
non-'model' matrix dim shards over ``dp`` (GSPMD all-gathers at use).
Optimizer state mirrors parameters (Adafactor's factored stats drop the
reduced dim from the spec).  Caches/batches use a divisibility-driven
generic rule so every (arch x shape) cell gets a legal spec (e.g.
long_500k has batch 1 — nothing to shard over dp; GQA KV caches with 4-8
heads shard sequence over 'model' instead of heads).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "dp_axes",
    "param_specs",
    "opt_state_specs",
    "batch_specs",
    "cache_specs",
    "named",
]


def dp_axes(mesh: Mesh, tp: bool = True) -> Tuple[str, ...]:
    """FSDP/DP axes.  With tp=False the 'model' axis folds into FSDP."""
    base = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return base if tp else base + ("model",)


def _detp(spec: P, fsdp) -> P:
    """Replace 'model' by None in a spec (tp disabled); the fsdp group
    already includes 'model' via dp_axes(mesh, tp=False)."""
    dims = []
    for ax in spec:
        if ax == "model":
            dims.append(None)
        elif isinstance(ax, tuple) and "model" in ax:
            dims.append(tuple(a for a in ax if a != "model") or None)
        else:
            dims.append(ax)
    return P(*dims)


def named(mesh: Mesh, spec_tree):
    """Bind a PartitionSpec tree to ``mesh`` as NamedSharding leaves.

    Args:
        mesh: The device mesh to bind to.
        spec_tree: Pytree of ``jax.sharding.PartitionSpec`` leaves.

    Returns:
        The same tree with each spec wrapped in ``NamedSharding``.
    """
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# --------------------------------------------------------------------- params

_COL = (  # (in, out): shard out dim over 'model', in over fsdp
    "wq", "wk", "wv", "w1", "w3", "up", "in_proj", "w_uq", "up1", "up2",
    "dt_proj",
)
_ROW = ("wo", "w2", "down", "out_proj")  # shard in dim over 'model'
_DIN = ("w_dq", "w_dkv", "proj", "w_in")  # (d_model, small): fsdp on d only
_REP = ("router", "w_kr", "r", "bias", "w_gn")  # replicated


def _spec_for(path: Tuple[str, ...], leaf, fsdp, moe_ep: bool = False) -> P:
    name = path[-1]
    nd = leaf.ndim
    inside_moe = "ffn" in path and nd == 3
    if inside_moe:
        if moe_ep:  # experts over 'model' (EP storage = EP compute layout)
            if name in ("w1", "w3", "w2"):
                return P("model", fsdp, None)
        if name in ("w1", "w3"):
            return P(None, fsdp, "model")
        if name == "w2":
            return P(None, "model", fsdp)
    if name == "e":  # embedding (V, D)
        return P("model", None)
    if name == "unembed":
        return P(None, "model")
    if name in ("w_uk", "w_uv"):  # (kv_lora, H*dim): col-parallel
        return P(None, "model")
    if name in _REP:
        return P(*([None] * nd))
    if name in _DIN and nd == 2:
        return P(fsdp, None)
    if name in _COL and nd == 2:
        return P(fsdp, "model")
    if name in _ROW and nd == 2:
        return P("model", fsdp)
    if name == "conv_w":  # (K, d_inner)
        return P(None, "model")
    if name in ("conv_b", "d_skip", "dt_bias", "skip_scale") and nd == 1:
        return P("model")
    if name == "a_log":  # (d_inner, N)
        return P("model", None)
    if name in ("wi", "wf") and nd == 2:  # mlstm gates (dp, H)
        return P("model", None)
    # norms / scalars / small leftovers: replicated
    return P(*([None] * nd))


def _fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes from dims they don't divide (e.g. seamless's
    vocab 256206 is not 16-divisible -> its embedding replicates)."""
    dims = []
    for i, axes in enumerate(spec):
        if axes is None:
            dims.append(None)
            continue
        ax = (axes,) if isinstance(axes, str) else tuple(axes)
        size = int(np.prod([mesh.shape[a] for a in ax]))
        dims.append(axes if shape[i] % size == 0 else None)
    return P(*dims)


def param_specs(params, mesh: Mesh, tp: bool = True, moe_ep: bool = False):
    """PartitionSpec tree matching ``params``; scanned stacks (leading
    n_periods dim) get a leading None prepended automatically.  Any axis
    that does not divide its dim falls back to replication."""
    fsdp = dp_axes(mesh, tp)

    def walk(path, leaf):
        """Spec for one parameter leaf (scan-stacked leaves handled)."""
        names = tuple(
            k.key if hasattr(k, "key") else str(k) for k in path
        )
        stacked = names and names[0] == "stack" or (
            len(names) > 1 and names[0] == "encoder" and names[1] == "stack"
        )
        if stacked:
            # leading scan dim
            sub = jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype)
            base = _spec_for(names, sub, fsdp, moe_ep)
            if not tp:
                base = _detp(base, fsdp)
            return P(None, *_fit_spec(base, sub.shape, mesh))
        base = _spec_for(names, leaf, fsdp, moe_ep)
        if not tp:
            base = _detp(base, fsdp)
        return _fit_spec(base, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(walk, params)


def opt_state_specs(opt_state, pspecs, params, mesh: Mesh):
    """Mirror parameter specs onto optimizer state.

    AdamW m/v have param shapes; Adafactor vr drops the last dim and vc
    the second-to-last.  Dispatch by shape matching.
    """
    flatp = {
        tuple(k.key if hasattr(k, "key") else str(k) for k in kp): (l, s)
        for (kp, l), (_, s) in zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_flatten_with_path(pspecs)[0],
        )
    }

    def walk(path, leaf):
        """Spec for one optimizer-state leaf via its parameter's spec."""
        names = tuple(k.key if hasattr(k, "key") else str(k) for k in path)
        if names[-1] == "gnorm":
            return P()
        # strip the optimizer-state prefix ('m'/'v'/'f') and suffix
        # ('vr'/'vc'/'v') to find the underlying parameter path
        core = names[1:]
        suffix = None
        if core and core[-1] in ("vr", "vc", "v"):
            suffix = core[-1]
            if core[:-1] in flatp:
                core = core[:-1]
        if core not in flatp:
            return P(*([None] * leaf.ndim))
        p_leaf, p_spec = flatp[core]
        if leaf.shape == p_leaf.shape:
            return p_spec
        if suffix == "vr" and leaf.shape == p_leaf.shape[:-1]:
            return P(*p_spec[:-1])
        if suffix == "vc" and leaf.shape == p_leaf.shape[:-2] + p_leaf.shape[-1:]:
            return P(*(tuple(p_spec[:-2]) + (p_spec[-1],)))
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(walk, opt_state)


# ------------------------------------------------------------- batch / cache


def _divisible(n: int, mesh: Mesh, axes) -> bool:
    if isinstance(axes, str):
        axes = (axes,)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return n % size == 0 and n >= size


def batch_specs(batch, mesh: Mesh, tp: bool = True):
    """PartitionSpec tree for a batch: dim 0 over dp when divisible.

    Args:
        batch: Pytree of batch arrays.
        mesh: The device mesh.
        tp: Whether a 'model' axis is in use (affects the dp group).

    Returns:
        Matching PartitionSpec tree; non-divisible leaves replicate.
    """
    dp = dp_axes(mesh, tp)

    def walk(leaf):
        """Spec for one batch leaf."""
        if leaf.ndim == 0:
            return P()
        dims: list = [None] * leaf.ndim
        if _divisible(leaf.shape[0], mesh, dp):
            dims[0] = dp
        return P(*dims)

    return jax.tree_util.tree_map(walk, batch)


def cache_specs(cache, mesh: Mesh, tp: bool = True):
    """Generic rule: batch dim over dp when divisible; then the largest
    remaining dim divisible by |model| shards over 'model'."""
    dp = dp_axes(mesh, tp)
    msize = mesh.shape["model"] if tp else 1

    def walk(leaf):
        """Spec for one cache leaf."""
        if leaf.ndim == 0:
            return P()
        dims: list = [None] * leaf.ndim
        if _divisible(leaf.shape[0], mesh, dp):
            dims[0] = dp
        best, best_size = None, 0
        if msize > 1:
            for i in range(1, leaf.ndim):
                if leaf.shape[i] % msize == 0 and leaf.shape[i] > best_size:
                    best, best_size = i, leaf.shape[i]
        if best is not None:
            dims[best] = "model"
        return P(*dims)

    return jax.tree_util.tree_map(walk, cache)
