"""Fault tolerance & straggler policy for 1000+ node deployments.

Mechanisms implemented in this repo (and where):

1. **Checkpoint/restart** — atomic sharded checkpoints
   (checkpoint/checkpointing.py: tmp-dir + fsync + rename; LATEST
   pointer validated against complete checkpoints), stateless data
   (data/pipeline.py: batch = f(seed, step)), bit-exact resume proven by
   tests/test_substrate.py::test_train_restart_is_bit_exact.

2. **Elastic scaling** — checkpoints store *global* arrays; restore
   re-shards onto whatever mesh the restoring job brings
   (checkpoint.restore(..., shardings=new_mesh_specs)).  A 256-chip
   checkpoint loads on 512 chips and vice versa; covered by
   tests/test_substrate_extra.py::test_elastic_reshard_roundtrip.

3. **Node-failure handling** — the runbook encoded in
   ``watchdog_restart`` below: on a missing heartbeat the coordinator
   re-launches the job on the surviving slice; because (1) is exact and
   (2) tolerates a smaller mesh, a failed pod degrades throughput, not
   correctness.  jax.distributed's coordination-service barrier is the
   hook point on real clusters (single-process here).

4. **Straggler mitigation** —
   * deterministic collective bucketing: grads reduce in a fixed layer
     order (the scan carries them in program order), so no device waits
     on out-of-order bucket arrival;
   * the grad-accum microbatch scan lets XLA overlap reduce-scatter of
     microbatch k with compute of k+1 (latency hiding measured in §Perf);
   * cross-pod (DCN) traffic can be compressed 2-4x with error feedback
     (distributed/compression.py) — slow links stop being the long pole.

5. **Multi-run consistency** — the step counter lives inside the jitted
   train state; checkpoints embed it; restarts can't double-apply.
"""

from __future__ import annotations

import os
import time
from typing import Callable, Optional

__all__ = ["watchdog_restart", "Heartbeat"]


class Heartbeat:
    """File-based heartbeat: each host touches its file every step;
    the coordinator treats a stale file as a failed host.  On real
    clusters this is replaced by the jax.distributed coordination
    service; the file protocol keeps the logic testable here."""

    def __init__(self, dir_: str, host: int):
        self.path = os.path.join(dir_, f"host_{host}.hb")
        os.makedirs(dir_, exist_ok=True)

    def beat(self):
        """Touch this host's heartbeat file with the current time."""
        with open(self.path, "w") as f:
            f.write(str(time.time()))

    @staticmethod
    def stale_hosts(dir_: str, timeout_s: float):
        """Host ids whose heartbeat is older than ``timeout_s`` seconds.

        Args:
            dir_: Heartbeat directory.
            timeout_s: Staleness threshold in seconds.

        Returns:
            Sorted list of failed host ids.
        """
        now = time.time()
        out = []
        for f in os.listdir(dir_):
            if f.endswith(".hb"):
                t = float(open(os.path.join(dir_, f)).read() or 0)
                if now - t > timeout_s:
                    out.append(int(f.split("_")[1].split(".")[0]))
        return sorted(out)


def watchdog_restart(
    train_fn: Callable[[Optional[int]], None],
    ckpt_dir: str,
    max_restarts: int = 100,
):
    """Supervision loop: run training; on any crash, resume from the
    latest complete checkpoint.  Used by tests to simulate node failure
    (the train_fn raises mid-run) and by launch scripts as the outermost
    wrapper on a real cluster."""
    from repro.checkpoint.checkpointing import latest_step

    restarts = 0
    while True:
        try:
            start = latest_step(ckpt_dir)
            train_fn(start)
            return restarts
        except Exception:  # noqa: BLE001 — any failure triggers restart
            restarts += 1
            if restarts > max_restarts:
                raise
