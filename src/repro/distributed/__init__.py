"""distributed subpackage."""
