"""Multi-device execution: partition rules, sharding, fault tolerance.

* ``simplex_sharding`` — equal-volume fold partitions of any
  ``SimplexSchedule`` over a mesh axis, the ``shard_skew`` metric, and
  the sharded CA executors (engine per-shard / shard_map + ppermute) —
  DESIGN.md §7.
* ``sharding`` — LM parameter/optimizer/batch/cache partition rules.
* ``fault_tolerance`` — heartbeat files and the ``watchdog_restart``
  supervision loop.
* ``compression`` — DCN-hop gradient compression with error feedback.
"""

from repro.distributed.simplex_sharding import (  # noqa: F401
    ShardedSimplexCA,
    ShardSchedule,
    StepShard,
    fold_partition,
    shard_mesh,
    shard_schedules,
    shard_skew,
    shard_state,
    sharded_ca,
    slab_skew,
)

__all__ = [
    "StepShard",
    "ShardSchedule",
    "fold_partition",
    "shard_schedules",
    "shard_skew",
    "slab_skew",
    "shard_mesh",
    "shard_state",
    "ShardedSimplexCA",
    "sharded_ca",
]
