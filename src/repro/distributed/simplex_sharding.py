"""Multi-device simplex sharding with skew control (DESIGN.md §7).

The paper's map H wins on one device by never launching the dead half
of the bounding box.  At cluster scale the same waste reappears as
*load skew*: naively slicing an m-simplex into equal-thickness slabs
along one axis gives the base slab up to m x the block volume of the
apex slab (the tetrahedral block-space imbalance of arXiv 1606.08881).
The fix is the same move the paper makes on-device, applied across
devices: partition the *schedule's step list* — the parallel space,
which enumerates exactly the live blocks — instead of the bounding
geometry.

``fold_partition`` generalizes ``core.schedule.folded_causal_pairs``
(query tile i paired with n-1-i) from m=2 to every dimension: the step
list is folded end-over-end (step 0, step S-1, step 1, step S-2, ...)
and dealt into k contiguous chunks of the folded order.  Each chunk
unfolds to at most TWO contiguous ranges of the original step order —
one near the apex, one near the base — so every shard keeps the seam
locality a halo exchange needs while its step count stays within one
block of ``S/k``.  ``shard_skew`` (max/mean shard block volume) is
therefore bounded by ``1 + k/S`` for the fold, versus ~m for the naive
slab split (``slab_skew`` quantifies the baseline).

``ShardSchedule`` exposes a shard as a first-class schedule — the same
``.grid`` / ``.steps`` / ``.map`` / ``.prefetch`` surface kernels
consume — so the ``SimplexKernel`` engine launches one shard exactly
like a full walk (``SimplexKernel(..., schedule=shard)``).  Seam halos
need no new machinery: the engine's 3^m-neighborhood subsystem already
fetches every neighbor tile of each scheduled block, so a seam face is
simply a neighbor fetch that lands on a tile *owned* by the adjacent
shard (DESIGN.md §7 seam-halo protocol).

Two executors drive a sharded CA step, both bit-exact against the
single-device engine:

* ``executor='engine'`` (default) — one per-shard ``SimplexKernel``
  launch, placed round-robin over the mesh devices; owned blocks are
  stitched with disjoint ownership masks.
* ``executor='spmd'`` — ``shard_map`` over a named mesh axis with the
  state held in a ``NamedSharding`` (axis-0 element slabs); seam planes
  travel by ``jax.lax.ppermute`` and each device steps its slab with
  true-coordinate domain masking.

Run ``examples/simplex_ca.py --devices k`` (under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on a host) for
the end-to-end story: a long sharded CA that checkpoints through
``checkpoint/checkpointing.py`` and survives a simulated worker loss
via ``distributed.fault_tolerance.watchdog_restart``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.schedule import SimplexSchedule, resolve_kind
from repro.core.simplex import simplex_volume

__all__ = [
    "StepShard",
    "ShardSchedule",
    "fold_partition",
    "shard_schedules",
    "shard_skew",
    "slab_skew",
    "shard_mesh",
    "shard_state",
    "ShardedSimplexCA",
    "sharded_ca",
]


# ---------------------------------------------------------------------------
# partition construction
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StepShard:
    """One shard of a folded step-list partition.

    Attributes:
        index: Shard number in ``[0, k)``.
        k: Total shard count of the partition.
        ranges: Up to two ``(start, stop)`` half-open ranges of the base
            schedule's step order — the apex-side and base-side runs the
            fold pairs together (merged when they touch).
    """

    index: int
    k: int
    ranges: Tuple[Tuple[int, int], ...]

    @property
    def steps(self) -> int:
        """Total steps (block volume) this shard owns."""
        return sum(b - a for a, b in self.ranges)


def fold_partition(n_steps: int, k: int) -> Tuple[StepShard, ...]:
    """Fold a step list end-over-end into k balanced shards.

    The folded order visits steps ``0, S-1, 1, S-2, ...`` — the
    dimension-generic form of the ``folded_causal_pairs`` pairing
    ``(i, n-1-i)`` — and is dealt into k contiguous chunks whose sizes
    differ by at most one.  A contiguous chunk of the folded order
    unfolds to one range near each end of the original order, so every
    shard is at most two contiguous step ranges: skew stays within
    ``1 + k/S`` of perfect while seam count stays O(1) per shard.

    Args:
        n_steps: Length S of the step list to partition.
        k: Shard count, ``1 <= k <= n_steps``.

    Returns:
        Tuple of k ``StepShard``; together a disjoint cover of
        ``range(n_steps)``.

    Example:
        >>> [s.ranges for s in fold_partition(6, 3)]
        [((0, 1), (5, 6)), ((1, 2), (4, 5)), ((2, 4),)]
        >>> from repro.core.schedule import folded_causal_pairs
        >>> folded_causal_pairs(4).tolist()   # the m=2 special case ...
        [[0, 3], [1, 2]]
        >>> [s.ranges for s in fold_partition(4, 2)]  # ... is k = S/2
        [((0, 1), (3, 4)), ((1, 3),)]
    """
    if k < 1 or k > n_steps:
        raise ValueError(
            f"need 1 <= k <= n_steps, got k={k}, n_steps={n_steps}"
        )
    base, rem = divmod(n_steps, k)
    shards = []
    p0 = 0
    for s in range(k):
        p1 = p0 + base + (1 if s < rem else 0)
        front = ((p0 + 1) // 2, (p1 + 1) // 2)
        back = (n_steps - p1 // 2, n_steps - p0 // 2)
        ranges = tuple(
            (a, b) for a, b in (front, back) if b > a
        )
        if len(ranges) == 2 and ranges[0][1] == ranges[1][0]:
            ranges = ((ranges[0][0], ranges[1][1]),)
        shards.append(StepShard(index=s, k=k, ranges=ranges))
        p0 = p1
    return tuple(shards)


def shard_skew(schedule: SimplexSchedule, k: int) -> float:
    """Max/mean shard block volume of the folded k-way partition.

    The fold deals steps one at a time, so shard sizes differ by at
    most one block and the skew is bounded by ``1 + k/steps`` — below
    1.05 for every realistic launch (``steps >= 20k``), versus the ~m x
    imbalance of the naive slab split (``slab_skew``).

    Args:
        schedule: Any ``SimplexSchedule`` (O(1): only ``.steps`` is
            read — no table build).
        k: Shard count.

    Returns:
        ``max(shard steps) / mean(shard steps)`` over the k shards.

    Example:
        >>> from repro.core.schedule import SimplexSchedule
        >>> shard_skew(SimplexSchedule(3, 8, "table"), 4)  # 120 = 4*30
        1.0
        >>> round(shard_skew(SimplexSchedule(2, 100, "composite"), 8), 4)
        1.0012
    """
    sizes = [s.steps for s in fold_partition(schedule.steps, k)]
    return max(sizes) / (sum(sizes) / len(sizes))


def slab_skew(m: int, nb: int, k: int) -> float:
    """Block-volume skew of the naive equal-thickness axis-0 slab split.

    Layer ``l`` of the blocked m-simplex holds ``l+1`` blocks at m=2
    (row l of the inclusive lower triangle) and ``V^{m-1}(nb - l)``
    blocks at m >= 3; slicing the nb layers into k equal-thickness
    slabs therefore loads the base slab up to m x the mean — the
    imbalance the fold partition removes.

    Args:
        m: Simplex dimension.
        nb: Tile (block) count per side.
        k: Slab count, ``1 <= k <= nb``.

    Returns:
        ``max(slab volume) / mean(slab volume)`` over the k slabs.

    Example:
        >>> round(slab_skew(3, 8, 4), 3)   # base slab 64 vs mean 30
        2.133
        >>> round(slab_skew(2, 64, 8), 3)  # ~2x at m=2, as the paper's fold predicts
        1.862
    """
    if k < 1 or k > nb:
        raise ValueError(f"need 1 <= k <= nb, got k={k}, nb={nb}")
    if m == 2:
        vols = [lo + 1 for lo in range(nb)]
    else:
        vols = [simplex_volume(nb - lo, m - 1) for lo in range(nb)]
    base, rem = divmod(nb, k)
    sums, lo = [], 0
    for s in range(k):
        hi = lo + base + (1 if s < rem else 0)
        sums.append(sum(vols[lo:hi]))
        lo = hi
    return max(sums) / (sum(sums) / len(sums))


# ---------------------------------------------------------------------------
# shard schedules: the engine-facing surface
# ---------------------------------------------------------------------------


def _is_jax(x) -> bool:
    return type(x).__module__.startswith("jax")


class ShardSchedule:
    """A shard of a base schedule, exposed as a launchable schedule.

    Wraps a ``SimplexSchedule`` restricted to one ``StepShard``: the
    same ``.grid`` / ``.steps`` / ``.map`` / ``.prefetch`` surface the
    ``SimplexKernel`` engine consumes, so
    ``SimplexKernel(body, m, schedule=shard)`` launches exactly the
    shard's blocks.  The map decodes a shard-local linear index into
    the base step order (piecewise over the <= 2 ranges), then into the
    base grid's coordinates — pure index arithmetic, dual-backend.

    Example:
        >>> from repro.core.schedule import SimplexSchedule
        >>> base = SimplexSchedule(3, 4, "table")
        >>> shards = shard_schedules(base, 4)
        >>> [s.steps for s in shards]
        [5, 5, 5, 5]
        >>> import numpy as np
        >>> tabs = np.concatenate([s.table() for s in shards])
        >>> sorted(map(tuple, tabs)) == sorted(map(tuple, base.table()))
        True
    """

    kind = "shard"

    def __init__(self, base: SimplexSchedule, shard: StepShard):
        if shard.steps < 1:
            raise ValueError(f"empty shard {shard.index} of {shard.k}")
        self.base = base
        self.shard = shard
        self.m = base.m
        self.n = base.n
        self.grid = (shard.steps,)
        self.steps = shard.steps
        self.useful = shard.steps
        self.ranges = shard.ranges

    @property
    def prefetch(self):
        """The base schedule's scalar-prefetch payload (table kinds)."""
        return self.base.prefetch

    def _global(self, lin):
        """Shard-local linear index -> base step-order index."""
        (a0, b0) = self.ranges[0]
        if len(self.ranges) == 1:
            return a0 + lin
        (a1, _) = self.ranges[1]
        l0 = b0 - a0
        if _is_jax(lin):
            import jax.numpy as jnp

            return jnp.where(lin < l0, a0 + lin, a1 + (lin - l0))
        return np.where(lin < l0, a0 + lin, a1 + (lin - l0))

    def map(self, lin, *prefetch):
        """Shard-local index -> ``(*coords, valid)`` of the base walk.

        Args:
            lin: Linear index/array in ``[0, self.steps)``.
            *prefetch: The prefetched table ref for table-driven bases.

        Returns:
            The base schedule's ``(*coords, valid)`` at the mapped step.
        """
        g = self._global(lin)
        ws, rem = [], g
        for gdim in self.base.grid:
            ws.append(rem % gdim)
            rem = rem // gdim
        return self.base.map(*ws, *prefetch)

    def table(self) -> np.ndarray:
        """Host-side ``(steps, m+1)`` walk table of this shard only."""
        lin = np.arange(self.steps, dtype=np.int64)
        if self.prefetch is not None:
            out = self.map(lin, self.prefetch)
        else:
            out = self.map(lin)
        cols = [np.asarray(c) for c in out[:-1]]
        cols.append(np.asarray(out[-1]).astype(np.int64))
        return np.stack(cols, axis=1).astype(np.int32)

    def owned_block_mask(self) -> np.ndarray:
        """Boolean ``(nb,)*m`` mask of blocks this shard owns.

        Valid steps only (array-axis order) — the stitching mask of the
        per-shard engine executor.  Host-side, O(shard steps).
        """
        tab = self.table()
        ok = tab[:, -1] != 0
        coords = tab[ok, : self.m]
        mask = np.zeros((self.n,) * self.m, dtype=bool)
        # table columns are math-order coords; array axis 0 is the last
        mask[tuple(coords[:, self.m - 1 - j] for j in range(self.m))] = True
        return mask

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"ShardSchedule({self.shard.index}/{self.shard.k}, "
            f"m={self.m}, n={self.n}, ranges={self.ranges}, "
            f"base={self.base.kind!r})"
        )


def shard_schedules(base: SimplexSchedule, k: int) -> Tuple[ShardSchedule, ...]:
    """Fold a schedule into k engine-launchable shard schedules.

    Args:
        base: The schedule to partition (any registered kind).
        k: Shard count, ``1 <= k <= base.steps``.

    Returns:
        k ``ShardSchedule`` whose step sets disjointly cover the base
        walk (fold partition: <= 2 contiguous ranges per shard).

    Example:
        >>> from repro.core.schedule import SimplexSchedule
        >>> subs = shard_schedules(SimplexSchedule(2, 16, "hmap"), 8)
        >>> sum(s.steps for s in subs), max(s.steps for s in subs)
        (136, 17)
    """
    return tuple(
        ShardSchedule(base, s) for s in fold_partition(base.steps, k)
    )


# ---------------------------------------------------------------------------
# mesh / layout helpers
# ---------------------------------------------------------------------------


def shard_mesh(k: int, axis: str = "shard"):
    """A 1-D device mesh of size k over the first k local devices.

    Args:
        k: Device count (<= ``jax.device_count()``; emulate on a host
            with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
        axis: Mesh axis name.

    Returns:
        ``jax.sharding.Mesh`` with one named axis of size k.
    """
    import jax
    from jax.sharding import Mesh

    devs = jax.devices()
    if len(devs) < k:
        raise ValueError(
            f"need {k} devices, found {len(devs)}; emulate with "
            "XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{k} (set before the first jax import)"
        )
    return Mesh(np.asarray(devs[:k]), (axis,))


def shard_state(state, mesh, axis: str = "shard"):
    """Place a domain array in the axis-0 slab ``NamedSharding`` layout.

    Args:
        state: ``(n,)*m`` domain array, ``n`` divisible by the mesh
            axis size.
        mesh: Mesh from ``shard_mesh``.
        axis: Mesh axis name to shard axis 0 over.

    Returns:
        ``state`` committed to ``NamedSharding(mesh, P(axis, None...))``.
    """
    import jax
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    k = mesh.shape[axis]
    if state.shape[0] % k != 0:
        raise ValueError(
            f"axis 0 ({state.shape[0]}) must divide over {k} devices"
        )
    spec = P(axis, *([None] * (state.ndim - 1)))
    return jax.device_put(state, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# sharded CA execution
# ---------------------------------------------------------------------------


class ShardedSimplexCA:
    """k-way sharded CA stepping, bit-exact vs the single-device engine.

    ``executor='engine'``: each shard is one ``SimplexKernel('ca', ...)``
    launch over its ``ShardSchedule``, placed round-robin on the mesh
    devices; every shard reads the same input generation (the engine's
    3^m-neighborhood subsystem serves seam halos from neighbor-shard
    tiles present in its input), and the output generation is stitched
    from the disjoint per-shard ownership masks — so the composition is
    bit-identical to one fused launch.

    ``executor='spmd'``: one ``shard_map`` program over the mesh axis
    with the state in the axis-0 slab ``NamedSharding``; seam planes
    travel by ``ppermute`` and each device steps its slab under
    true-coordinate domain masking (free boundaries at m >= 3, periodic
    wrap at m=2 — the engine's per-dimension CA conventions).

    Args:
        m: Simplex dimension (>= 2).
        n: Domain side length in elements.
        k: Shard count.
        rho: Tile side for the engine executor (default
            ``engine.default_rho(m)``).
        kind: Base schedule kind (resolved via ``resolve_kind``).
        mesh: Optional mesh from ``shard_mesh``; None runs all shards
            on the default device (partition semantics unchanged).
        interpret: Pallas mode, None = per-backend policy.
    """

    def __init__(self, m: int, n: int, k: int, *, rho: Optional[int] = None,
                 kind: str = "hmap", mesh=None, interpret=None,
                 axis: str = "shard"):
        from repro.kernels.engine import SimplexKernel, default_rho

        self.m, self.n, self.k = m, n, k
        self.rho = default_rho(m) if rho is None else rho
        if n % self.rho != 0:
            raise ValueError(f"rho={self.rho} must divide n={n}")
        self.nb = n // self.rho
        self.kind = resolve_kind(m, self.nb, kind)
        self.mesh = mesh
        self.axis = axis
        self.interpret = interpret
        base = SimplexSchedule(m, self.nb, self.kind)
        self.base = base
        self.shards = shard_schedules(base, k)
        self._kernels = [
            SimplexKernel("ca", m, rho=self.rho, kind=self.kind,
                          interpret=interpret, schedule=sh)
            for sh in self.shards
        ]
        self._devices = None
        if mesh is not None:
            self._devices = list(mesh.devices.flat)
        self._masks = None  # element ownership masks, built lazily

    # -- engine executor ---------------------------------------------------

    def _ownership_masks(self):
        import jax.numpy as jnp

        if self._masks is None:
            reps = (self.rho,) * self.m
            masks = []
            for sh in self.shards:
                blk = sh.owned_block_mask()
                for ax, r in enumerate(reps):
                    blk = np.repeat(blk, r, axis=ax)
                masks.append(jnp.asarray(blk))
            self._masks = masks
        return self._masks

    def step_engine(self, state):
        """One CA generation via per-shard engine launches + stitching."""
        import jax
        import jax.numpy as jnp

        masks = self._ownership_masks()
        outs = []
        for i, kern in enumerate(self._kernels):
            x = state
            if self._devices is not None:
                x = jax.device_put(
                    state, self._devices[i % len(self._devices)]
                )
            outs.append(kern(x))
        out = state
        for y, mask in zip(outs, masks):
            if self._devices is not None:
                y = jax.device_get(y)
            out = jnp.where(mask, jnp.asarray(y), out)
        return out

    # -- SPMD executor -----------------------------------------------------

    def step_spmd(self, state):
        """One CA generation via shard_map + ppermute seam exchange.

        ``state`` may be host-resident or already committed to the slab
        ``NamedSharding``; the output keeps the sharded layout.
        """
        import jax

        if self.mesh is None:
            raise ValueError("executor='spmd' needs a mesh (shard_mesh(k))")
        if self.n % self.k != 0:
            raise ValueError(
                f"spmd executor slabs elements: n={self.n} must divide "
                f"over k={self.k}"
            )
        fn = _spmd_step_fn(self.m, self.n, self.k, self.mesh, self.axis)
        return fn(shard_state(jax.numpy.asarray(state), self.mesh, self.axis))

    def step(self, state, executor: str = "engine"):
        """One CA generation with the chosen executor."""
        if executor == "engine":
            return self.step_engine(state)
        if executor == "spmd":
            return self.step_spmd(state)
        raise ValueError(f"unknown executor {executor!r}")

    def run(self, state, steps: int, executor: str = "engine"):
        """``steps`` generations from ``state``; returns the final one."""
        for _ in range(steps):
            state = self.step(state, executor=executor)
        return state


_SPMD_CACHE = {}


def _spmd_step_fn(m: int, n: int, k: int, mesh, axis: str):
    """Build (and cache) the jitted shard_map CA step for (m, n, k)."""
    import functools

    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    key = (m, n, k, axis, tuple(d.id for d in mesh.devices.flat))
    if key in _SPMD_CACHE:
        return _SPMD_CACHE[key]

    slab = n // k
    spec = P(axis, *([None] * (m - 1)))
    periodic = m == 2
    fwd = [(i, (i + 1) % k) for i in range(k)]
    bwd = [(i, (i - 1) % k) for i in range(k)]

    def local_mask(idx):
        # true-coordinate domain mask of this device's slab
        shape = (slab,) + (n,) * (m - 1)
        coords = [
            jax.lax.broadcasted_iota(jnp.int32, shape, j)
            for j in range(m)
        ]
        coords[0] = coords[0] + idx * slab
        if m == 2:
            return coords[1] <= coords[0]
        total = coords[0]
        for c in coords[1:]:
            total = total + c
        return total < n

    def _step(local):
        idx = jax.lax.axis_index(axis)
        msk = local_mask(idx)
        s = jnp.where(msk, local, 0)
        # seam halo: one element plane each way along the sharded axis
        up = jax.lax.ppermute(s[-1:], axis, fwd)    # prev shard's base plane
        down = jax.lax.ppermute(s[:1], axis, bwd)   # next shard's apex plane
        if not periodic:
            up = jnp.where(idx == 0, 0, up)
            down = jnp.where(idx == k - 1, 0, down)
        padded = jnp.concatenate([up, s, down], axis=0)
        # remaining axes are fully local: wrap (m=2) or zero-pad (m>=3)
        for ax in range(1, m):
            if periodic:
                lo = jax.lax.slice_in_dim(padded, n - 1, n, axis=ax)
                hi = jax.lax.slice_in_dim(padded, 0, 1, axis=ax)
            else:
                shape = list(padded.shape)
                shape[ax] = 1
                lo = hi = jnp.zeros(shape, padded.dtype)
            padded = jnp.concatenate([lo, padded, hi], axis=ax)
        neigh = jnp.zeros_like(s)
        for shift in np.ndindex(*(3,) * m):
            if all(d == 1 for d in shift):
                continue
            sl = tuple(
                slice(d, d + dim) for d, dim in zip(shift, s.shape)
            )
            neigh = neigh + padded[sl]
        born = (s == 0) & (neigh == 3)
        survive = (s == 1) & ((neigh == 2) | (neigh == 3))
        new = (born | survive).astype(local.dtype)
        # engine semantics: out-of-domain elements keep their input value
        return jnp.where(msk, new, local)

    fn = jax.jit(
        shard_map(_step, mesh=mesh, in_specs=spec, out_specs=spec)
    )
    _SPMD_CACHE[key] = fn
    return fn


def sharded_ca(state, k: int, steps: int = 1, *, rho: Optional[int] = None,
               kind: str = "hmap", mesh=None, executor: str = "engine",
               interpret=None):
    """Run ``steps`` sharded CA generations on an ``(n,)*m`` state.

    Convenience wrapper over ``ShardedSimplexCA`` — bit-equal to
    ``steps`` applications of the single-device engine CA
    (``kernels.engine.ca`` / ``ca_md``).

    Args:
        state: ``(n,)*m`` 0/1 array (m = state.ndim >= 2).
        k: Shard count.
        steps: Generations to run.
        rho: Engine tile side (engine executor).
        kind: Base schedule kind.
        mesh: Mesh from ``shard_mesh`` (None = default device only).
        executor: ``'engine'`` or ``'spmd'``.
        interpret: Pallas mode (None = per-backend policy).

    Returns:
        The final generation, same shape/dtype as ``state``.
    """
    runner = ShardedSimplexCA(
        state.ndim, state.shape[0], k, rho=rho, kind=kind, mesh=mesh,
        interpret=interpret,
    )
    return runner.run(state, steps, executor=executor)
