"""Gradient compression for the DCN ('pod') axis, with error feedback.

At 1000+ nodes the cross-pod data-parallel reduction runs over DCN
(25-100x slower than ICI); compressing just that hop is the standard
lever.  Provided here:

* ``compress_bf16`` — 2x: cast grads to bf16 for the cross-pod reduce,
  accumulate the rounding error locally and add it back next step
  (error feedback keeps convergence unbiased).
* ``compress_int8`` — 4x: per-tensor absmax int8 quantization + error
  feedback.

Usage inside a train step (pod axis present):

    comp, new_err = compress_bf16(grads, err)
    grads = psum_over('pod', comp)        # cheap DCN hop
    grads = psum_over(('data',), grads)   # full-precision ICI hop

The dry-run's §Perf cross-pod iteration measures the wire-byte effect;
convergence parity is asserted in tests/test_substrate_extra.py.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_bf16", "compress_int8", "init_error_state"]


def init_error_state(params_like: Any) -> Any:
    """Zero f32 error-feedback accumulators shaped like ``params_like``."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params_like
    )


def compress_bf16(grads: Any, err: Any) -> Tuple[Any, Any]:
    """Returns (bf16 grads-with-feedback, new error state)."""

    def one(g, e):
        """Quantize one leaf; carry the rounding error forward."""
        gf = g.astype(jnp.float32) + e
        q = gf.astype(jnp.bfloat16)
        return q, gf - q.astype(jnp.float32)

    out = jax.tree_util.tree_map(one, grads, err)
    comp = jax.tree_util.tree_map(
        lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_err = jax.tree_util.tree_map(
        lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    return comp, new_err


def compress_int8(grads: Any, err: Any) -> Tuple[Any, Any]:
    """Per-tensor absmax int8; returns ((q, scale) tree, new error)."""

    def one(g, e):
        """Quantize one leaf; carry the quantization error forward."""
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return (q, scale), gf - deq

    out = jax.tree_util.tree_map(one, grads, err)
    comp = jax.tree_util.tree_map(
        lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_err = jax.tree_util.tree_map(
        lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    return comp, new_err


def decompress_int8(comp: Any) -> Any:
    """Dequantize a ``compress_int8`` tree back to f32 gradients."""

    def one(qs):
        """Dequantize one (q, scale) leaf."""
        q, scale = qs
        return q.astype(jnp.float32) * scale

    return jax.tree_util.tree_map(
        one, comp, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
    )
