"""End-to-end trainer with checkpoint/restart fault tolerance.

Runs for real on CPU-sized configs (the examples use it); the same code
path drives the production mesh on TPU.  Features exercised here:
deterministic data (step -> batch), atomic checkpoints + resume-latest,
grad accumulation, and the folded-simplex attention schedule.

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
      --steps 200 --ckpt-dir /tmp/ckpt --resume
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--schedule-steps", type=int, default=0,
                    help="LR schedule horizon (defaults to --steps); set "
                    "explicitly when a run will be interrupted + resumed")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M params presets)")
    ap.add_argument("--n-layers", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.checkpoint import checkpointing as ckpt
    from repro.configs.ALL import REDUCED
    from repro.configs.base import get_config
    from repro.data.pipeline import SyntheticLM
    from repro.models.model import Model
    from repro.optim.optimizer import make_optimizer, warmup_cosine

    cfg = REDUCED[args.arch]() if args.smoke else get_config(args.arch)
    over = {"act_dtype": "float32", "param_dtype": "float32", "remat": "none"}
    if args.d_model:
        over["d_model"] = args.d_model
    if args.n_layers:
        over["n_layers"] = args.n_layers
    cfg = cfg.replace(**over)

    model = Model(cfg)
    horizon = args.schedule_steps or args.steps
    opt = make_optimizer(
        cfg.optimizer, warmup_cosine(args.lr, horizon // 10 + 1, horizon)
    )
    data = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=args.seed)

    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    step0 = 0
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params:,} steps={args.steps}")

    if args.resume and args.ckpt_dir:
        proto = {"params": params, "opt": opt_state}
        restored, s = ckpt.restore_latest(args.ckpt_dir, proto)
        if restored is not None:
            params, opt_state = restored["params"], restored["opt"]
            step0 = s
            print(f"resumed from step {s}")

    nmb = args.microbatches

    @jax.jit
    def train_step(params, opt_state, step, batch):
        def loss_fn(p, mb):
            l, m = model.loss(p, mb)
            return l

        if nmb > 1:
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:]),
                batch,
            )
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def acc(c, mb):
                g_acc, l_acc = c
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (
                    jax.tree_util.tree_map(lambda a, b: a + b, g_acc, g),
                    l_acc + l,
                ), None

            (grads, loss), _ = jax.lax.scan(acc, (zero, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / nmb, grads)
            loss = loss / nmb
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_p, new_o = opt.update(grads, opt_state, params, step)
        return new_p, new_o, loss

    t0 = time.time()
    losses = []
    for step in range(step0, args.steps):
        batch = data.batch_at(step)
        params, opt_state, loss = train_step(
            params, opt_state, jnp.asarray(step), batch
        )
        losses.append(float(loss))
        if step % args.log_every == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq * (step - step0 + 1) / (time.time() - t0)
            print(f"step {step:5d}  loss {float(loss):.4f}  tok/s {tok_s:,.0f}")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, {"params": params, "opt": opt_state})
            print(f"checkpoint @ {step + 1}")
    print(f"first-loss {losses[0]:.4f}  last-loss {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
