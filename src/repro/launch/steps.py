"""Builds the jitted, sharded step functions for every (arch x shape).

``train_step``  — fwd+bwd (+grad-accum microbatch scan) + optimizer
``prefill_step``— full-sequence forward producing caches
``serve_step``  — one decoded token against a full cache

All three are what the multi-pod dry-run lowers and compiles, and what
``launch/train.py`` / ``launch/serve.py`` execute for real on small
configs.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCfg
from repro.distributed.sharding import (
    batch_specs,
    cache_specs,
    dp_axes,
    named,
    opt_state_specs,
    param_specs,
)
from jax.sharding import NamedSharding
from repro.models.model import Model
from repro.optim.optimizer import make_optimizer, warmup_cosine

__all__ = ["StepBundle", "build"]


class StepBundle:
    """Holds the jitted step + abstract inputs + shardings for one cell."""

    def __init__(self, cfg: ArchConfig, mesh, shape: ShapeCfg):
        self.cfg = cfg
        self.mesh = mesh
        if getattr(cfg, "microbatches_override", 0) and shape.mode == "train":
            import dataclasses
            shape = dataclasses.replace(
                shape, microbatches=cfg.microbatches_override
            )
        self.shape = shape
        self.model = Model(cfg)
        self.params_sds = jax.eval_shape(
            lambda: self.model.init(jax.random.PRNGKey(0))
        )
        tp = cfg.tp_size > 1
        self.tp = tp
        moe_ep = bool(cfg.moe) and (cfg.moe_impl or cfg.moe.impl) == "ep"
        self.moe_ep = moe_ep
        raw_specs = param_specs(self.params_sds, mesh, tp, moe_ep)
        if shape.mode != "train" and cfg.weights_resident_serve:
            from jax.sharding import PartitionSpec as _P

            def _drop_fsdp(spec):
                dims = []
                for ax in spec:
                    axes = (ax,) if isinstance(ax, str) else (ax or ())
                    if any(a in ("pod", "data") for a in axes):
                        kept = tuple(a for a in axes if a not in ("pod", "data"))
                        dims.append(kept if len(kept) > 1 else
                                    (kept[0] if kept else None))
                    else:
                        dims.append(ax)
                return _P(*dims)

            raw_specs = jax.tree_util.tree_map(
                _drop_fsdp, raw_specs, is_leaf=lambda x: isinstance(x, _P)
            )
        self.pspecs = named(mesh, raw_specs)
        if shape.mode == "train":
            self.opt = make_optimizer(
                cfg.optimizer, warmup_cosine(3e-4, 2000, 100_000)
            )
            self.opt_sds = jax.eval_shape(self.opt.init, self.params_sds)
            raw_p = param_specs(self.params_sds, mesh, tp, moe_ep)
            self.ospecs = named(
                mesh,
                opt_state_specs(self.opt_sds, raw_p, self.params_sds, mesh),
            )
        self.batch_sds = self.model.input_specs(shape)
        self.bspecs = named(mesh, batch_specs(self.batch_sds, mesh, tp))
        if shape.mode == "decode":
            self.cache_sds = jax.eval_shape(
                lambda: self.model.init_cache(
                    shape.global_batch, shape.seq_len, jnp.bfloat16
                )
            )
            self.cspecs = named(mesh, cache_specs(self.cache_sds, mesh, tp))

    # ------------------------------------------------------------------ train

    def train_step_fn(self):
        model, mesh, nmb = self.model, self.mesh, self.shape.microbatches
        gdt = jnp.dtype(self.cfg.gather_dtype)

        def loss_fn(p, mb):
            l, metrics = model.loss(p, mb, mesh)
            return l, metrics

        def train_step(params, opt_state, step, batch):
            if gdt != jnp.dtype(self.cfg.param_dtype):
                # cast while still sharded: the FSDP all-gather then moves
                # gather_dtype bytes; grads return in gather_dtype and the
                # optimizer applies them to the full-precision master.
                params_c = jax.tree_util.tree_map(
                    lambda p: p.astype(gdt) if p.ndim >= 2 else p, params
                )
            else:
                params_c = params
            if nmb > 1:
                mbs = jax.tree_util.tree_map(
                    lambda x: x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:]),
                    batch,
                )
                zero = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )

                def acc(carry, mb):
                    g_acc, l_acc = carry
                    (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        params_c, mb
                    )
                    g_acc = jax.tree_util.tree_map(
                        lambda a, b: a + b.astype(jnp.float32), g_acc, g
                    )
                    return (g_acc, l_acc + l), None

                (grads, loss), _ = jax.lax.scan(
                    acc, (zero, jnp.zeros((), jnp.float32)), mbs
                )
                grads = jax.tree_util.tree_map(lambda g: g / nmb, grads)
                loss = loss / nmb
            else:
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params_c, batch
                )
            new_p, new_o = self.opt.update(grads, opt_state, params, step)
            return new_p, new_o, step + 1, {"loss": loss}

        return train_step

    def jit_train(self):
        return jax.jit(
            self.train_step_fn(),
            in_shardings=(
                self.pspecs, self.ospecs, NamedSharding(self.mesh, P()),
                self.bspecs,
            ),
            out_shardings=(
                self.pspecs, self.ospecs, NamedSharding(self.mesh, P()),
                NamedSharding(self.mesh, P()),
            ),
            donate_argnums=(0, 1),
        )

    def lower_train(self):
        step_sds = jax.ShapeDtypeStruct((), jnp.int32)
        with self.mesh:
            return self.jit_train().lower(
                self.params_sds, self.opt_sds, step_sds, self.batch_sds
            )

    # -------------------------------------------------------------- prefill

    def prefill_step_fn(self):
        model, mesh = self.model, self.mesh

        def prefill_step(params, batch):
            return model.prefill(params, batch, mesh)

        return prefill_step

    def lower_prefill(self):
        with self.mesh:
            return jax.jit(
                self.prefill_step_fn(),
                in_shardings=(self.pspecs, self.bspecs),
            ).lower(self.params_sds, self.batch_sds)

    # ---------------------------------------------------------------- decode

    def serve_step_fn(self):
        model, mesh = self.model, self.mesh

        def serve_step(params, caches, batch):
            return model.decode(params, caches, batch, mesh)

        return serve_step

    def lower_serve(self):
        with self.mesh:
            return jax.jit(
                self.serve_step_fn(),
                in_shardings=(self.pspecs, self.cspecs, self.bspecs),
            ).lower(self.params_sds, self.cache_sds, self.batch_sds)

    # ------------------------------------------------------------------ main

    def lower(self):
        if self.shape.mode == "train":
            return self.lower_train()
        if self.shape.mode == "prefill":
            return self.lower_prefill()
        return self.lower_serve()


def build(cfg: ArchConfig, mesh, shape: ShapeCfg) -> StepBundle:
    return StepBundle(cfg, mesh, shape)
