import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before any jax import: jax locks the
#   device count at first initialization.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this prints/records:
  * compiled.memory_analysis()  — proves the cell fits per-device HBM
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline
  * the collective-op byte census parsed from the compiled HLO text

Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json and are
aggregated by repro.roofline.analysis into EXPERIMENTS.md tables.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import time
import traceback


def cell_skip_reason(cfg, shape_name: str):
    from repro.configs.base import SHAPES

    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return (
            "long_500k needs sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (DESIGN.md §5)"
        )
    return None


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: str,
             overrides=None) -> dict:
    import jax
    import numpy as np

    from repro.configs.base import SHAPES, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build
    from repro.roofline.analysis import collective_census, roofline_terms
    from repro.roofline.hlo_cost import analyze_hlo

    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "mode": shape.mode, "status": "ok",
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
    }
    skip = cell_skip_reason(cfg, shape_name)
    if skip:
        rec.update(status="skip", reason=skip)
        _write(outdir, mesh_name, arch, shape_name, rec, overrides)
        print(f"[SKIP] {arch} x {shape_name}: {skip}")
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        bundle = build(cfg, mesh, shape)
        lowered = bundle.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        print(mem)
        ca = compiled.cost_analysis()
        print({k: ca.get(k) for k in ("flops", "bytes accessed")})
        hlo = compiled.as_text()
        census = collective_census(hlo)  # single-pass (loop bodies once)
        cost = analyze_hlo(hlo)  # loop-aware: trip-count multiplied
        n_chips = int(np.prod(list(mesh.shape.values())))
        rec.update(
            seconds_lower=round(t_lower, 1),
            seconds_compile=round(t_compile, 1),
            n_chips=n_chips,
            # loop-aware GLOBAL totals: the compiled HLO is the per-device
            # SPMD program, so x n_chips (cost_analysis also counts while
            # bodies once — see roofline/hlo_cost.py); raw kept for reference
            # flops: loop-aware dot/MXU flops (elementwise excluded — the
            # MFU convention).  bytes: loop-aware operand+result bytes at
            # the CPU backend's fusion granularity — an upper bound on TPU
            # HBM traffic (TPU fuses more); relative comparisons between
            # variants of the same cell are reliable (see roofline docs).
            flops=float(cost["flops"]) * n_chips,
            bytes_accessed=float(cost["bytes"]) * n_chips,
            loop_bytes_factor=float(cost["loop_bytes_factor"]),
            flops_raw_costanalysis=float(ca.get("flops", 0.0)),
            bytes_raw_costanalysis=float(ca.get("bytes accessed", 0.0)),
            memory={
                "argument_size": mem.argument_size_in_bytes,
                "output_size": mem.output_size_in_bytes,
                "temp_size": mem.temp_size_in_bytes,
                "alias_size": mem.alias_size_in_bytes,
                "generated_code_size": mem.generated_code_size_in_bytes,
            },
            collectives={
                "per_kind": cost["per_kind"],
                "wire_bytes_per_chip": cost["wire_bytes_per_chip"],
                "single_pass": census,
            },
            params=int(sum(
                int(np.prod(x.shape))
                for x in jax.tree_util.tree_leaves(bundle.params_sds)
            )),
            params_active=cfg.active_param_count(),
            tokens=shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1),
            attention_schedule=cfg.attention_schedule,
            remat=cfg.remat,
            microbatches=shape.microbatches if shape.mode == "train" else 1,
        )
        rec["roofline"] = roofline_terms(rec)
        print(
            f"[OK] {arch} x {shape_name} ({mesh_name}): "
            f"lower {t_lower:.0f}s compile {t_compile:.0f}s  "
            f"flops {rec['flops']:.3g}  coll_bytes {census['wire_bytes_per_chip']:.3g}"
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        print(f"[ERR] {arch} x {shape_name}: {e}")
    _write(outdir, mesh_name, arch, shape_name, rec, overrides)
    return rec


def _write(outdir, mesh_name, arch, shape_name, rec, overrides=None):
    d = os.path.join(outdir, mesh_name)
    os.makedirs(d, exist_ok=True)
    tag = ""
    if overrides:
        tag = "__" + "_".join(f"{k}={v}" for k, v in sorted(overrides.items()))
        tag = tag.replace("/", "-")[:80]
    with open(os.path.join(d, f"{arch}__{shape_name}{tag}.json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (e.g. attention_schedule=bb)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            pass
        overrides[k] = v

    from repro.configs.ALL import ARCH_IDS
    from repro.configs.base import SHAPES

    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                run_cell(arch, shape, args.multi_pod, args.outdir,
                         overrides or None)
    else:
        run_cell(args.arch, args.shape, args.multi_pod, args.outdir,
                 overrides or None)


if __name__ == "__main__":
    main()
