"""launch subpackage."""
