"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: (16, 16) = 256 chips,
('data', 'model').  Multi-pod: (2, 16, 16) = 512 chips,
('pod', 'data', 'model') — 'pod' is the DCN-spanning axis.
"""

from __future__ import annotations

import numpy as np

__all__ = ["make_production_mesh", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    assert len(devs) >= n, (
        f"need {n} devices, found {len(devs)} — the dry-run entrypoint sets "
        "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
        "jax import"
    )
    return jax.make_mesh(
        shape, axes, devices=devs[:n],
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def make_mesh(shape, axes):
    """Small helper for tests (e.g. (2, 2) meshes on 4 host devices)."""
    import jax

    n = int(np.prod(shape))
    return jax.make_mesh(
        shape, axes, devices=jax.devices()[:n],
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )
