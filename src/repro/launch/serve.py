"""Batched serving driver: prefill a batch of prompts, decode N tokens.

Runs for real on reduced configs; on the production mesh the same
serve_step is what the decode_32k / long_500k dry-run cells compile.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args(argv)

    from repro.configs.ALL import REDUCED
    from repro.configs.base import get_config
    from repro.models.model import Model

    cfg = REDUCED[args.arch]() if args.smoke else get_config(args.arch)
    cfg = cfg.replace(act_dtype="float32", param_dtype="float32", remat="none")
    model = Model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)

    b, s = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.n_patches:
        batch["tokens"] = batch["tokens"][:, : s - cfg.n_patches]
        batch["patches"] = jax.random.normal(
            key, (b, cfg.n_patches, cfg.d_model), jnp.float32
        )
    if cfg.encoder_layers:
        batch["src_embeds"] = jax.random.normal(key, (b, s, cfg.d_model))

    prefill = jax.jit(lambda p, bt: model.prefill(p, bt))
    t0 = time.time()
    logits, caches = prefill(params, batch)
    jax.block_until_ready(logits)
    print(f"prefill {s} tokens x {b}: {time.time()-t0:.2f}s")

    # NOTE on cache semantics: serve decodes against the *fixed* prefill
    # cache (the decode_32k cell's workload); production ring-buffer
    # append is a size/bookkeeping change, not a compute one.
    decode = jax.jit(lambda p, c, bt: model.decode(p, c, bt))
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(args.gen):
        step_batch = {"tokens": tok, "pos": jnp.full((b,), s + i, jnp.int32)}
        logits, _ = decode(params, caches, step_batch)
        key, k2 = jax.random.split(key)
        if args.temperature > 0:
            tok = jax.random.categorical(
                k2, logits[:, -1] / args.temperature, -1
            )[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(
        f"decoded {args.gen} tokens x {b} in {dt:.2f}s "
        f"({args.gen*b/dt:.1f} tok/s)"
    )
    gen = np.concatenate([np.asarray(t) for t in out_tokens], 1)
    print("sample token ids:", gen[0][:16].tolist())
    return gen


if __name__ == "__main__":
    main()
