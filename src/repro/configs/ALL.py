"""Import side-effect: registers all ten assigned architectures."""

from . import (  # noqa: F401
    deepseek_v3_671b,
    granite_8b,
    internlm2_20b,
    jamba_v01_52b,
    qwen2_moe_a27b,
    qwen2_vl_72b,
    seamless_m4t_large_v2,
    stablelm_12b,
    xlstm_350m,
    yi_6b,
)

ARCH_IDS = [
    "seamless-m4t-large-v2",
    "stablelm-12b",
    "yi-6b",
    "granite-8b",
    "internlm2-20b",
    "deepseek-v3-671b",
    "qwen2-moe-a2.7b",
    "qwen2-vl-72b",
    "jamba-v0.1-52b",
    "xlstm-350m",
]

REDUCED = {
    "seamless-m4t-large-v2": seamless_m4t_large_v2.reduced,
    "stablelm-12b": stablelm_12b.reduced,
    "yi-6b": yi_6b.reduced,
    "granite-8b": granite_8b.reduced,
    "internlm2-20b": internlm2_20b.reduced,
    "deepseek-v3-671b": deepseek_v3_671b.reduced,
    "qwen2-moe-a2.7b": qwen2_moe_a27b.reduced,
    "qwen2-vl-72b": qwen2_vl_72b.reduced,
    "jamba-v0.1-52b": jamba_v01_52b.reduced,
    "xlstm-350m": xlstm_350m.reduced,
}
