"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352 [hf:stabilityai/stablelm-2-12b; hf]."""

from .base import ArchConfig, LayerSpec, register

FULL = register(ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab=100352,
    period=(LayerSpec("attn", "dense"),),
    optimizer="adafactor",
    source="hf:stabilityai/stablelm-2-12b",
))


def reduced() -> ArchConfig:
    return FULL.replace(
        name="stablelm-12b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab=512, attention_chunk=32,
    )
