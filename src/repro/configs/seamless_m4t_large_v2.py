"""seamless-m4t-large-v2 [audio] — enc-dec multimodal backbone
[arXiv:2308.11596; hf].  24L d_model=1024 16H (GQA kv=16) d_ff=8192
vocab=256206.  The speech/audio frontend is a STUB: input_specs provides
precomputed frame embeddings of width d_model to the encoder."""

from .base import ArchConfig, LayerSpec, register

FULL = register(ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,                    # decoder layers
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    period=(LayerSpec("attn", "dense"),),
    rope_theta=10_000.0,
    optimizer="adamw",
    source="arXiv:2308.11596; hf",
))


def reduced() -> ArchConfig:
    return FULL.replace(
        name="seamless-m4t-large-v2-smoke", n_layers=2, encoder_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
        attention_chunk=32,
    )
