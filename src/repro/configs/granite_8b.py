"""granite-8b [dense] — llama-arch, code.  36L d_model=4096 32H (GQA
kv=8) d_ff=14336 vocab=49152 [arXiv:2405.04324; hf]."""

from .base import ArchConfig, LayerSpec, register

FULL = register(ArchConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=49152,
    period=(LayerSpec("attn", "dense"),),
    optimizer="adamw",
    source="arXiv:2405.04324; hf",
))


def reduced() -> ArchConfig:
    return FULL.replace(
        name="granite-8b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512, attention_chunk=32,
    )
