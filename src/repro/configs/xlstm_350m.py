"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517;
unverified].  24L d_model=1024 4H vocab=50304; d_ff=0 in the assignment
means the blocks carry their own projections (mLSTM proj x2, sLSTM FFN
x4/3), per the xLSTM paper.  Ratio 7:1 mLSTM:sLSTM per 8-block period.
Sub-quadratic: constant-size recurrent state; runs long_500k."""

from .base import ArchConfig, LayerSpec, XLSTMCfg, register

_PERIOD = tuple(
    LayerSpec("slstm" if i == 3 else "mlstm", "none") for i in range(8)
)

FULL = register(ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    xlstm=XLSTMCfg(n_heads=4, chunk=64),
    period=_PERIOD,
    sub_quadratic=True,
    optimizer="adamw",
    source="arXiv:2405.04517",
))


def reduced() -> ArchConfig:
    return FULL.replace(
        name="xlstm-350m-smoke", n_layers=8, d_model=64, vocab=512,
        n_heads=4, n_kv_heads=4,
        xlstm=FULL.xlstm.__class__(n_heads=4, chunk=16),
        attention_chunk=32,
    )
