"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].  24L d_model=2048 16H (GQA kv=16)
d_ff(expert)=1408 vocab=151936."""

from .base import ArchConfig, LayerSpec, MoECfg, register

FULL = register(ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    moe=MoECfg(n_experts=60, top_k=4, expert_ff=1408, n_shared=4,
               shared_ff=5632),
    period=(LayerSpec("attn", "moe"),),
    optimizer="adamw",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
))


def reduced() -> ArchConfig:
    return FULL.replace(
        name="qwen2-moe-a2.7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=64,
        moe=FULL.moe.__class__(n_experts=6, top_k=2, expert_ff=64,
                               n_shared=2, shared_ff=128),
        vocab=512, attention_chunk=32,
    )
