"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437; hf].  61L d_model=7168 128H d_ff(expert)=2048
vocab=129280.  First 3 layers dense (d_ff=18432, HF config); MLA ranks
q_lora=1536 kv_lora=512 rope=64 nope=128 v=128 (HF config); the
assignment line pins the MoE geometry (256e top-8, expert_ff=2048,
1 shared)."""

from .base import ArchConfig, LayerSpec, MLACfg, MoECfg, register

FULL = register(ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                     # dense prefix layers (HF config)
    vocab=129280,
    head_dim=192,                   # qk_nope(128) + qk_rope(64)
    attention="mla",
    mla=MLACfg(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
               qk_rope_dim=64, v_head_dim=128),
    moe=MoECfg(n_experts=256, top_k=8, expert_ff=2048, n_shared=1,
               shared_ff=2048, router="sigmoid"),
    n_prefix=3,
    prefix_spec=(LayerSpec("attn", "dense"),) * 3,
    period=(LayerSpec("attn", "moe"),),
    mtp=True,
    optimizer="adafactor",
    source="arXiv:2412.19437; hf",
))


def reduced() -> ArchConfig:
    return FULL.replace(
        name="deepseek-v3-671b-smoke", n_layers=3, n_prefix=1,
        prefix_spec=(LayerSpec("attn", "dense"),),
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
        head_dim=24,
        mla=FULL.mla.__class__(q_lora_rank=48, kv_lora_rank=32,
                               qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
        moe=FULL.moe.__class__(n_experts=8, top_k=2, expert_ff=32,
                               n_shared=1, shared_ff=32, router="sigmoid"),
        attention_chunk=32,
    )
