"""internlm2-20b [dense] — GQA.  48L d_model=6144 48H (GQA kv=8)
d_ff=16384 vocab=92544 [arXiv:2403.17297; hf]."""

from .base import ArchConfig, LayerSpec, register

FULL = register(ArchConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92544,
    period=(LayerSpec("attn", "dense"),),
    optimizer="adafactor",
    source="arXiv:2403.17297; hf",
))


def reduced() -> ArchConfig:
    return FULL.replace(
        name="internlm2-20b-smoke", n_layers=2, d_model=96, n_heads=6,
        n_kv_heads=2, d_ff=192, vocab=512, attention_chunk=32,
    )
