"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191;
hf].  80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.  Vision
frontend is a STUB: input_specs provides n_patches=1024 precomputed
patch embeddings (32x32 grid) prepended to the text tokens; M-RoPE
sections (16, 24, 24) over head_dim/2 = 64 frequency slots."""

from .base import ArchConfig, LayerSpec, register

FULL = register(ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    period=(LayerSpec("attn", "dense"),),
    mrope_sections=(16, 24, 24),
    n_patches=1024,
    optimizer="adafactor",
    source="arXiv:2409.12191; hf",
))


def reduced() -> ArchConfig:
    return FULL.replace(
        name="qwen2-vl-72b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512, n_patches=16,
        mrope_sections=(4, 2, 2), attention_chunk=32,
    )
