"""jamba-v0.1-52b [hybrid] — Mamba+attn 1:7 interleave, MoE
[arXiv:2403.19887; hf].  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2.  Period of 8: attention at index 4
(attn_layer_offset=4), MoE on odd layers (every 2, e_offset=1) — the HF
Jamba layout.  Sub-quadratic: runs the long_500k cell (SSM state + 1/8
attention layers with KV cache)."""

from .base import ArchConfig, LayerSpec, MambaCfg, MoECfg, register

_PERIOD = tuple(
    LayerSpec("attn" if i == 4 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)

FULL = register(ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    moe=MoECfg(n_experts=16, top_k=2, expert_ff=14336),
    mamba=MambaCfg(d_state=16, d_conv=4, expand=2),
    period=_PERIOD,
    sub_quadratic=True,
    optimizer="adafactor",
    source="arXiv:2403.19887; hf",
))


def reduced() -> ArchConfig:
    return FULL.replace(
        name="jamba-v0.1-52b-smoke", n_layers=8, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128,
        moe=FULL.moe.__class__(n_experts=4, top_k=2, expert_ff=128),
        vocab=512, attention_chunk=32,
    )
