"""Architecture configuration system.

Every assigned architecture is a frozen ``ArchConfig`` built in its own
module under ``repro/configs/`` with the exact numbers from the
assignment, plus a ``reduced()`` variant used by CPU smoke tests.
``REGISTRY`` maps ``--arch <id>`` names to configs.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

__all__ = [
    "LayerSpec",
    "MoECfg",
    "MLACfg",
    "MambaCfg",
    "XLSTMCfg",
    "ArchConfig",
    "REGISTRY",
    "register",
    "get_config",
    "SHAPES",
    "ShapeCfg",
]


@dataclass(frozen=True)
class LayerSpec:
    """One layer of a (possibly heterogeneous) period pattern."""

    mixer: str = "attn"  # attn | mamba | mlstm | slstm
    ffn: str = "dense"  # dense | moe | none


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    expert_ff: int
    n_shared: int = 0
    shared_ff: int = 0  # total ff of the shared expert(s)
    capacity_factor: float = 1.25
    router: str = "softmax"  # softmax | sigmoid (deepseek-v3)
    aux_loss_weight: float = 0.001
    impl: str = "tp"  # tp: expert-ff sharded over model | ep: experts over model


@dataclass(frozen=True)
class MLACfg:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMCfg:
    n_heads: int = 4
    proj_factor_mlstm: float = 2.0
    proj_factor_slstm: float = 4.0 / 3.0
    d_conv: int = 4
    chunk: int = 64  # chunkwise-parallel mLSTM chunk length


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # layer pattern: the model is scan(period) x (n_layers/len(period)),
    # after ``n_prefix`` unrolled prefix layers (deepseek dense head).
    period: Tuple[LayerSpec, ...] = (LayerSpec(),)
    n_prefix: int = 0
    prefix_spec: Tuple[LayerSpec, ...] = ()
    attention: str = "gqa"  # gqa | mla
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    mamba: Optional[MambaCfg] = None
    xlstm: Optional[XLSTMCfg] = None
    rope_theta: float = 1_000_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    # enc-dec (seamless): encoder_layers > 0 adds a bidirectional encoder
    # (stubbed modality frontend feeds it frame embeddings directly).
    encoder_layers: int = 0
    # vlm stub: n_patches of precomputed patch embeddings prepended
    n_patches: int = 0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    mtp: bool = False  # DeepSeek-V3 multi-token prediction head
    act_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    sub_quadratic: bool = False  # may run long_500k
    optimizer: str = "adamw"  # adamw | adafactor (big archs)
    remat: str = "full"  # none | full | dots
    attention_chunk: int = 512  # XLA chunked-attention tile
    attention_schedule: str = "folded"  # folded (simplex) | bb (baseline)
    # prefill/train attention executor: "auto" resolves through
    # autotune.choose_attn_impl (Pallas flash vs chunked XLA);
    # "flash" / "chunked" force a path, "flash-folded" / "flash-bb"
    # additionally pin the kernel schedule (benchmarks — DESIGN.md §8)
    attention_impl: str = "auto"
    # tensor-parallel width on the 'model' mesh axis.  16 = full TP
    # (default); 1 = fold the axis into FSDP/DP (right-sizes small
    # models: a 6B model on 256 chips needs no TP — §Perf iteration A2).
    tp_size: int = 16
    # overrides the shape's grad-accum microbatch count when > 0 (§Perf)
    microbatches_override: int = 0
    # dtype in which FSDP all-gathers move parameters ("bfloat16" halves
    # gather wire bytes; master copy stays param_dtype — §Perf A4)
    gather_dtype: str = "float32"
    # MoE distribution override: "" = MoECfg.impl; "ep" = expert parallel
    # (experts over 'model', token all-to-all); "tp" = expert-ff sharding
    moe_impl: str = ""
    # decode/prefill: keep weights resident (sharded over 'model' only,
    # replicated over dp) instead of ZeRO-3 — otherwise every decoded
    # token re-gathers the entire model (§Perf D1: jamba long_500k spends
    # 10.5 GB/token of wire on FSDP gathers).  Train keeps ZeRO-3.
    weights_resident_serve: bool = True
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_periods(self) -> int:
        body = self.n_layers - self.n_prefix
        assert body % len(self.period) == 0, (self.name, body, len(self.period))
        return body // len(self.period)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Total parameters (exact, from abstract init)."""
        import jax

        from repro.models.model import Model

        m = Model(self)
        shapes = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
        return sum(
            int(__import__("numpy").prod(x.shape))
            for x in jax.tree_util.tree_leaves(shapes)
        )

    def active_param_count(self) -> int:
        """Active parameters per token (MoE-aware), for MODEL_FLOPS."""
        total = self.param_count()
        if self.moe is None:
            return total
        # subtract the inactive routed-expert fraction
        import numpy as np

        moe_layers = 0
        specs = list(self.prefix_spec) + list(self.period) * self.n_periods
        for s in specs:
            moe_layers += s.ffn == "moe"
        per_expert = 3 * self.d_model * self.moe.expert_ff
        routed_total = moe_layers * self.moe.n_experts * per_expert
        routed_active = moe_layers * self.moe.top_k * per_expert
        return total - routed_total + routed_active


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode
    microbatches: int = 1


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train", microbatches=8),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    from . import ALL  # noqa: F401  (forces registration)

    return REGISTRY[name]
