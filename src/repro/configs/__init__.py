"""Assigned architecture configs; importing .ALL registers all ten."""
