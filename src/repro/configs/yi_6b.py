"""yi-6b [dense] — llama-arch GQA.  32L d_model=4096 32H (GQA kv=4)
d_ff=11008 vocab=64000 [arXiv:2403.04652; hf]."""

from .base import ArchConfig, LayerSpec, register

FULL = register(ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    period=(LayerSpec("attn", "dense"),),
    optimizer="adamw",
    source="arXiv:2403.04652; hf",
))


def reduced() -> ArchConfig:
    return FULL.replace(
        name="yi-6b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab=512, attention_chunk=32,
    )
