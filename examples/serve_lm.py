"""Batched serving example: prefill a batch of prompts and decode with
the KV-cache serve path (the decode_32k dry-run cell's workload, at
CPU scale).

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch jamba-v0.1-52b]
"""

import argparse

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b",
                    help="any assigned arch id (reduced config is used)")
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    serve_main([
        "--arch", args.arch, "--smoke", "--batch", "4",
        "--prompt-len", "64", "--gen", str(args.gen),
    ])


if __name__ == "__main__":
    main()
