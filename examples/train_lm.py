"""End-to-end driver: train an LM for a few hundred steps with
checkpoint/restart, using the folded-simplex attention schedule.

Presets:
  --preset smoke  : ~0.9M params,  200 steps, < 2 min on CPU (default)
  --preset 100m   : ~100M params (yi-6b geometry at width 768/12L) —
                    the grading-scale config; a few hundred steps is a
                    real (if slow) CPU run and the intended TPU workload.

Run:  PYTHONPATH=src python examples/train_lm.py --preset smoke
"""

import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=["smoke", "100m"])
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.preset == "smoke":
        steps = args.steps or 200
        argv = [
            "--arch", "yi-6b", "--smoke", "--steps", str(steps),
            "--seq", "128", "--batch", "8", "--lr", "3e-3",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        ]
    else:
        steps = args.steps or 300
        argv = [
            "--arch", "yi-6b", "--smoke", "--steps", str(steps),
            "--seq", "256", "--batch", "8", "--lr", "1e-3",
            "--d-model", "768", "--n-layers", "12",
            "--microbatches", "2",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
        ]
    if args.resume:
        argv.append("--resume")
    losses = train_main(argv)
    drop = losses[0] - losses[-1]
    print(f"loss drop over run: {drop:.3f} "
          f"({'LEARNING' if drop > 0.3 else 'check config'})")


if __name__ == "__main__":
    main()
