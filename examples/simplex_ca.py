"""Cellular automaton on simplex domains — the paper's flagship
application (§5.1: CA2D with periodic bounds, CA3D free bounds).

Runs Conway's game of life on a triangular domain with the H-grid
kernel and renders generations as ASCII; then steps a 3D tetrahedral
CA with the exact table schedule and prints live-cell counts.

Run:  PYTHONPATH=src python examples/simplex_ca.py [--steps 8] [--n 64]

Multi-device mode (DESIGN.md §7) runs a long sharded m=3 CA over k
devices with fold-partition load balancing, checkpointing every few
generations and surviving a simulated worker loss via the watchdog:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python examples/simplex_ca.py --devices 8 \\
      [--steps 12] [--fail-at 5] [--executor engine|spmd]

The final sharded state is asserted bit-equal to an uninterrupted
single-device engine run.
"""

import argparse
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels import ref as R


def render(state, max_rows=24):
    s = np.asarray(state)
    n = s.shape[0]
    step = max(1, n // max_rows)
    lines = []
    for r in range(0, n, step):
        row = s[r, : r + 1 : step]
        lines.append(" ".join("o" if c else "." for c in row))
    return "\n".join(lines)


def single_device_demo(args):
    n = args.n
    key = jax.random.PRNGKey(42)
    state = (jax.random.uniform(key, (n, n)) < 0.35).astype(jnp.int32)
    state = state * R.tril_mask(n, jnp.int32)
    print(f"2-simplex CA, n={n}, H-grid kernel "
          f"({(n//args.rho)//2}x{(n//args.rho)+1} blocks vs "
          f"{(n//args.rho)**2} for BB)")
    for t in range(args.steps):
        alive = int(state.sum())
        print(f"\n-- generation {t} (alive={alive}) --")
        print(render(state))
        state = ops.simplex_ca2d(state, rho=args.rho, kind="hmap")

    print("\n3-simplex CA (free boundaries, exact table schedule):")
    n3 = 32
    s3 = (jax.random.uniform(key, (n3, n3, n3)) < 0.3).astype(jnp.int32)
    s3 = s3 * R.tetra_mask(n3, jnp.int32)
    for t in range(4):
        print(f"  gen {t}: alive={int(s3.sum())}")
        s3 = ops.simplex_ca3d(s3, rho=4, kind="table")
    print(f"  gen 4: alive={int(s3.sum())}")


def sharded_demo(args):
    """Long sharded m=3 CA: fold partition + checkpoints + watchdog."""
    from repro.checkpoint import checkpointing as ckpt
    from repro.distributed.fault_tolerance import watchdog_restart
    from repro.distributed.simplex_sharding import (
        ShardedSimplexCA, shard_mesh, shard_skew,
    )

    k = args.devices
    if jax.device_count() < k:
        raise SystemExit(
            f"need {k} devices, found {jax.device_count()}; emulate with\n"
            "  XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{k} PYTHONPATH=src python examples/simplex_ca.py "
            f"--devices {k}"
        )
    n = args.n3
    mesh = shard_mesh(k)
    runner = ShardedSimplexCA(3, n, k, kind="table", mesh=mesh)
    print(f"3-simplex CA sharded over {k} devices "
          f"(n={n}, {runner.base.steps} blocks, fold skew "
          f"{shard_skew(runner.base, k):.4f})")
    for sh in runner.shards:
        print(f"  shard {sh.shard.index}: {sh.steps} blocks, "
              f"step ranges {sh.ranges}")

    key = jax.random.PRNGKey(7)
    init = (jax.random.uniform(key, (n, n, n)) < 0.3).astype(jnp.int32)
    init = np.asarray(init * R.tetra_mask(n, jnp.int32))

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="simplex_ca_ckpt_")
    fail_at = {"step": args.fail_at}  # one-shot simulated worker loss

    def train(start_step):
        """Resume-from-checkpoint CA loop (the watchdog's train_fn)."""
        if start_step is None:
            state, t0 = init, 0
        else:
            tree, t0 = ckpt.restore_latest(ckpt_dir, {"state": init})
            state = np.asarray(tree["state"])
            print(f"  [watchdog] resumed from checkpoint step {t0}")
        state = jnp.asarray(state)
        for t in range(t0, args.steps):
            if fail_at["step"] is not None and t == fail_at["step"]:
                fail_at["step"] = None
                raise RuntimeError(
                    f"simulated worker loss at generation {t}"
                )
            state = runner.step(state, executor=args.executor)
            if (t + 1) % args.ckpt_every == 0 or t + 1 == args.steps:
                ckpt.save(ckpt_dir, t + 1, {"state": np.asarray(state)})
            print(f"  gen {t + 1}: alive={int(jnp.sum(state))}")
        return state

    restarts = watchdog_restart(train, ckpt_dir)
    print(f"watchdog restarts: {restarts}")
    tree, step = ckpt.restore_latest(ckpt_dir, {"state": init})
    final = np.asarray(tree["state"])

    # ground truth: uninterrupted single-device engine run
    want = init
    for _ in range(args.steps):
        want = np.asarray(ops.simplex_ca_md(jnp.asarray(want), kind="table"))
    exact = np.array_equal(want, final)
    print(f"sharded result bit-equals single-device engine: {exact}")
    if args.ckpt_dir is None:
        shutil.rmtree(ckpt_dir, ignore_errors=True)
    if not exact:
        raise SystemExit("sharded CA diverged from single-device engine")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--n3", type=int, default=32,
                    help="m=3 side length for --devices mode")
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--rho", type=int, default=8)
    ap.add_argument("--devices", type=int, default=0,
                    help="shard the m=3 CA over k devices (0 = off)")
    ap.add_argument("--executor", choices=("engine", "spmd"),
                    default="engine")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulate a worker loss at this generation")
    ap.add_argument("--ckpt-every", type=int, default=3)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    args = ap.parse_args()

    if args.devices:
        sharded_demo(args)
    else:
        single_device_demo(args)


if __name__ == "__main__":
    main()
