"""Cellular automaton on simplex domains — the paper's flagship
application (§5.1: CA2D with periodic bounds, CA3D free bounds).

Runs Conway's game of life on a triangular domain with the H-grid
kernel and renders generations as ASCII; then steps a 3D tetrahedral
CA with the exact table schedule and prints live-cell counts.

Run:  PYTHONPATH=src python examples/simplex_ca.py [--steps 8] [--n 64]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels import ref as R


def render(state, max_rows=24):
    s = np.asarray(state)
    n = s.shape[0]
    step = max(1, n // max_rows)
    lines = []
    for r in range(0, n, step):
        row = s[r, : r + 1 : step]
        lines.append(" ".join("o" if c else "." for c in row))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--rho", type=int, default=8)
    args = ap.parse_args()
    n = args.n

    key = jax.random.PRNGKey(42)
    state = (jax.random.uniform(key, (n, n)) < 0.35).astype(jnp.int32)
    state = state * R.tril_mask(n, jnp.int32)
    print(f"2-simplex CA, n={n}, H-grid kernel "
          f"({(n//args.rho)//2}x{(n//args.rho)+1} blocks vs "
          f"{(n//args.rho)**2} for BB)")
    for t in range(args.steps):
        alive = int(state.sum())
        print(f"\n-- generation {t} (alive={alive}) --")
        print(render(state))
        state = ops.simplex_ca2d(state, rho=args.rho, kind="hmap")

    print("\n3-simplex CA (free boundaries, exact table schedule):")
    n3 = 32
    s3 = (jax.random.uniform(key, (n3, n3, n3)) < 0.3).astype(jnp.int32)
    s3 = s3 * R.tetra_mask(n3, jnp.int32)
    for t in range(4):
        print(f"  gen {t}: alive={int(s3.sum())}")
        s3 = ops.simplex_ca3d(s3, rho=4, kind="table")
    print(f"  gen 4: alive={int(s3.sum())}")


if __name__ == "__main__":
    main()
