"""Quickstart: the paper's H map in 5 minutes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hmap2_full, tri
from repro.core.schedule import SimplexSchedule, registered_kinds, resolve_kind
from repro.kernels import ops
from repro.kernels import ref as R


def main():
    n_blocks = 16
    print("=" * 64)
    print("1. The block-space map H (paper Eq. 14-16 + zero-waste diagonal)")
    print("=" * 64)
    w, h = n_blocks // 2, n_blocks + 1
    print(f"super-orthotope grid: {w} x {h} = {w*h} blocks "
          f"== tri({n_blocks}) = {tri(n_blocks)} lower-triangle tiles")
    wy, wx = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    x, y = hmap2_full(wx.ravel(), wy.ravel(), n_blocks)
    grid = np.full((n_blocks, n_blocks), ".", dtype=object)
    for i, (a, b) in enumerate(zip(x, y)):
        grid[b, a] = "#"
    print("covered tiles (# = exactly once):")
    for row in grid:
        print(" ", "".join(row))

    print()
    print("=" * 64)
    print("2. One scheduling API for every dimension: SimplexSchedule")
    print("=" * 64)
    print("  SimplexSchedule(m, n, kind) -> .grid/.steps/.map/.waste()")
    for m in (2, 3, 4):
        print(f"  m={m} registered kinds: {registered_kinds(m)}")
    for nb in [16, 128, 1024]:
        s_h = SimplexSchedule(2, nb, "hmap").steps
        s_bb = SimplexSchedule(2, nb, "bb").steps
        print(f"  m=2 n={nb:5d}:  H {s_h:>9,} steps   BB {s_bb:>9,} steps   "
              f"ratio {s_bb/s_h:.3f}x  (the paper's MAP speedup)")
    print("  beyond the paper: the m>=4 recursive map (DESIGN.md §4)")
    for m in (3, 4, 5):
        sched = SimplexSchedule(m, 64, "hmap")
        bb = SimplexSchedule(m, 64, "bb")
        print(f"  m={m} n=64: H {sched.steps:>10,} steps "
              f"(waste {sched.waste():+.2f})   "
              f"BB {bb.steps:>12,}   ratio {bb.steps/sched.steps:.1f}x "
              f"(bound m! = {math.factorial(m)}x)")

    print()
    print("=" * 64)
    print("3. Any n, analytically: the composite decomposition (§4.2)")
    print("=" * 64)
    print("  non-pow2 n used to degrade to an O(V) host-side table walk;")
    print("  'hmap' now resolves to the composite piecewise map instead:")
    kind = resolve_kind(3, 100, "hmap")
    print(f"  resolve_kind(3, 100, 'hmap') -> {kind!r}")
    sched = SimplexSchedule(3, 100, kind)
    table = SimplexSchedule(3, 100, "table")
    print(f"  m=3 n=100: composite {sched.steps:,} steps "
          f"(waste {sched.waste():+.1%}, O(pieces) build)   "
          f"table {table.steps:,} steps (O(V) build)")
    sched4 = SimplexSchedule(4, 24, resolve_kind(4, 24, "hmap"))
    print(f"  m=4 n=24:  composite {sched4.steps:,} steps "
          f"(waste {sched4.waste():+.1%})")
    # the walk is exact: every cell of T(100) visited exactly once
    tab = sched.table()
    pts = tab[tab[:, -1] == 1, :3]
    assert len(np.unique(pts, axis=0)) == len(pts) == sched.useful
    print(f"  exhaustive check: {len(pts):,} cells of T(100) covered "
          f"exactly once: True")
    # and the m>=3 kernels consume it unchanged at non-pow2 block counts
    from repro.kernels import simplex_kernels as K
    x3 = jax.random.randint(jax.random.PRNGKey(3), (12, 12, 12), 0, 9)
    got3 = np.asarray(K.accum3d(x3.astype(jnp.int32), rho=2, kind="hmap"))
    m3 = np.indices((12,) * 3).sum(0) < 12
    ok3 = np.array_equal(got3[m3], np.asarray(x3)[m3] + 1)
    print(f"  ACCUM3D kernel at nb=6 (composite path) matches oracle: {ok3}")

    print()
    print("=" * 64)
    print("4. Pallas kernels on the simplex (validated vs jnp oracle)")
    print("=" * 64)
    key = jax.random.PRNGKey(0)
    xx = jax.random.randint(key, (64, 64), 0, 9).astype(jnp.int32)
    got = ops.simplex_accum2d(xx, rho=8, kind="hmap")
    want = R.accum2d(xx)
    m = np.asarray(R.tril_mask(64))
    ok = np.array_equal(np.asarray(got)[m], np.asarray(want)[m])
    print(f"  ACCUM kernel (H-grid) matches oracle: {ok}")

    p = jax.random.normal(key, (64, 8))
    got = ops.simplex_edm2d(p, rho=8, kind="hmap")
    want = R.edm2d(p)
    print("  EDM kernel (H-grid) max err:",
          float(jnp.abs((got - want) * R.tril_mask(64, jnp.float32)).max()))

    x4 = jax.random.randint(key, (8, 8, 8, 8), 0, 9).astype(jnp.int32)
    got4 = np.asarray(ops.simplex_accum_md(x4, rho=2, kind="hmap"))
    m4 = np.indices((8,) * 4).sum(0) < 8
    ok4 = np.array_equal(got4[m4], (np.asarray(x4) + 1)[m4])
    print(f"  ACCUM4D kernel (m=4 recursive H-grid) matches oracle: {ok4}")

    print()
    print("=" * 64)
    print("5. Causal attention IS a 2-simplex: folded flash kernel")
    print("=" * 64)
    q = jax.random.normal(key, (1, 4, 256, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 256, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 256, 32))
    out = ops.causal_flash_attention(q, k, v, kind="folded", block_q=64,
                                     block_kv=64)
    ref = R.causal_attention(q, k, v)
    print("  folded flash vs reference max err:",
          float(jnp.abs(out - ref).max()))
    from repro.kernels.flash_attention import flash_grid_steps
    print(f"  grid steps: folded {flash_grid_steps(4,'folded')} "
          f"vs bb {flash_grid_steps(4,'bb')}")


if __name__ == "__main__":
    main()
